"""Observability-layer tests.

Fast lane (runs in the main single-device pytest process): the
zero-overhead-off guarantee — instrumented functions traced while obs is
disabled produce HLO byte-identical to a never-enabled trace, with no
callback custom-calls — plus registry/sink unit behaviour and the kernel
dispatch validation.

Slow lane: the 8-device acceptance run (``tests/_obs_check.py``) in a
subprocess, mirroring tests/test_exchange.py — the main process must keep
a single device.
"""

import os
import pathlib
import re
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.kway import merge_kway

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# zero overhead when disabled
# ---------------------------------------------------------------------------


def _lower_merge_kway():
    fn = jax.jit(lambda runs: merge_kway(runs))
    return (
        fn.lower(jax.ShapeDtypeStruct((4, 32), jnp.int32))
        .compile()
        .as_text()
    )


# Debug metadata (op_name scopes, source_file/source_line) is not part of
# the compiled program: line attribution shifts with jax's trace-cache
# state (e.g. whose frame first traced jnp.where's inner jit), so the
# identity check compares the HLO with metadata stripped.
_HLO_METADATA_RE = re.compile(r", metadata=\{[^}]*\}")


def _canon(hlo: str) -> str:
    return _HLO_METADATA_RE.sub("", hlo)


def test_disabled_hlo_identical_and_callback_free():
    """Tier-1 guard: instrumentation must not change the compiled program
    while disabled — not after an enable/disable cycle either."""
    assert not obs.enabled()
    before = _lower_merge_kway()
    assert "custom-call" not in before

    with obs.capture():
        enabled_txt = _lower_merge_kway()
        assert "custom-call" in enabled_txt  # record points really trace

    after = _lower_merge_kway()
    assert _canon(after) == _canon(before), (
        "HLO of the disabled trace changed across an enable/disable cycle"
    )


def test_disabled_record_adds_no_jaxpr_ops():
    assert not obs.enabled()

    def f(x):
        obs.gauge("t.noop", x.sum())
        obs.counter("t.noop_c", 1)
        obs.histogram("t.noop_h", x)
        return x * 2

    jaxpr = str(jax.make_jaxpr(f)(jnp.arange(4)))
    assert "callback" not in jaxpr


# ---------------------------------------------------------------------------
# registry / sink behaviour
# ---------------------------------------------------------------------------


def test_counter_totals_accumulate():
    with obs.capture() as recs:
        obs.counter("t.hits", 5, tag="a")
        obs.counter("t.hits", jnp.arange(3))  # vector counter: summed
        obs.flush()
        assert obs.totals()["t.hits"] == 5 + (0 + 1 + 2)
        assert len([r for r in recs if r["metric"] == "t.hits"]) == 2


def test_histogram_summary_fields():
    with obs.capture() as recs:
        obs.histogram("t.dist", jnp.asarray([1.0, 2.0, 3.0, 4.0]))
        obs.flush()
        (r,) = [x for x in recs if x["metric"] == "t.dist"]
        assert r["kind"] == "histogram"
        assert r["count"] == 4
        assert r["min"] == 1.0 and r["max"] == 4.0 and r["sum"] == 10.0
        assert "p50" in r and "p90" in r


def test_traced_labels_forwarded_through_callback():
    with obs.capture() as recs:
        jax.jit(
            lambda x: (obs.gauge("t.lbl", x, device=jnp.int32(3)), x)[1]
        )(jnp.int32(7))
        obs.flush()
        (r,) = [x for x in recs if x["metric"] == "t.lbl"]
        assert r["value"] == 7
        assert r["labels"]["device"] == 3


def test_step_label_stamped():
    with obs.capture() as recs:
        obs.set_step(42)
        obs.gauge("t.stepped", 1.0)
        obs.flush()
        (r,) = [x for x in recs if x["metric"] == "t.stepped"]
        assert r["step"] == 42
    obs.set_step(None)


def test_enable_argument_validation():
    with pytest.raises(ValueError):
        obs.enable()
    with pytest.raises(ValueError):
        from repro.obs.sink import ListSink

        obs.enable(metrics_dir="/tmp/x", sink=ListSink())
    assert not obs.enabled()


def test_capture_nests_without_cross_talk():
    with obs.capture() as outer:
        obs.gauge("t.outer", 1)
        with obs.capture() as inner:
            obs.gauge("t.inner", 2)
            obs.flush()
        obs.gauge("t.outer", 3)
        obs.flush()
        assert [r["metric"] for r in inner] == ["t.inner"]
        outer_names = [r["metric"] for r in outer]
        assert outer_names.count("t.outer") == 2
        assert "t.inner" not in outer_names
    assert not obs.enabled()


def test_jsonl_sink_roundtrip(tmp_path):
    import json

    obs.enable(metrics_dir=str(tmp_path))
    try:
        obs.gauge("t.file", jnp.float32(1.5), tag="x")
        obs.log_event("t.event", detail="hello")
        obs.flush()
    finally:
        obs.disable()
    lines = (tmp_path / "metrics.jsonl").read_text().strip().splitlines()
    recs = [json.loads(line) for line in lines]
    metrics = {r["metric"] for r in recs}
    assert {"t.file", "t.event"} <= metrics


def test_log_event_safe_while_disabled(caplog):
    assert not obs.enabled()
    obs.log_event("t.disabled_event", reason="nothing should raise")


# ---------------------------------------------------------------------------
# kernel dispatch validation (satellite: no silent backend fall-through)
# ---------------------------------------------------------------------------


def test_invalid_backend_raises():
    from repro.kernels.ops import stable_merge, stable_sort

    a = jnp.asarray([1, 3], jnp.int32)
    b = jnp.asarray([2, 4], jnp.int32)
    with pytest.raises(ValueError, match="backend must be one of"):
        stable_merge(a, b, backend="palas")  # the typo must fail loudly
    with pytest.raises(ValueError, match="backend must be one of"):
        stable_sort(a, backend="PALLAS")


def test_invalid_backend_env_raises(monkeypatch):
    from repro.kernels.ops import BACKEND_ENV_VAR, default_backend

    monkeypatch.setenv(BACKEND_ENV_VAR, "cuda")
    with pytest.raises(ValueError, match=BACKEND_ENV_VAR):
        default_backend()


def test_dispatch_counter_and_one_time_log():
    from repro.kernels import ops

    a = jnp.asarray([1, 3], jnp.int32)
    b = jnp.asarray([2, 4], jnp.int32)
    ops._LOGGED_CHOICES.discard(("stable_merge", "xla", "arg"))
    with obs.capture() as recs:
        stable = np.asarray(ops.stable_merge(a, b, backend="xla"))
        np.testing.assert_array_equal(stable, [1, 2, 3, 4])
        ops.stable_merge(a, b, backend="xla")  # cached: no re-trace
        obs.flush()
        chosen = [
            r for r in recs if r["metric"] == "kernels.backend_selected"
        ]
        assert len(chosen) == 1  # announced once per distinct choice
        assert chosen[0]["labels"]["backend"] == "xla"
        assert chosen[0]["labels"]["source"] == "arg"
        assert obs.totals().get("kernels.dispatch_calls", 0) >= 1


# ---------------------------------------------------------------------------
# 8-device acceptance run (subprocess)
# ---------------------------------------------------------------------------


def _run(script: str, *args: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / script), *args],
        capture_output=True,
        text=True,
        env=env,
        timeout=1800,
    )
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    return proc.stdout


@pytest.mark.slow  # subprocess run on 8 fake devices
def test_obs_eight_devices():
    out = _run("_obs_check.py")
    assert "ALL OK" in out
    assert "Prop-1 iteration counters within bound: OK" in out
    assert "HLO reconciliation" in out
