"""Dropless expert-parallel MoE checks, run in a subprocess with 8 fake
host devices.

Invoked by tests/test_moe_dropless.py; exits nonzero on any failure.
Covers the acceptance criteria of the dropless dispatch refactor:

* ``distributed_segment_cuts`` columns equal the
  ``distributed_co_rank_kway`` cut vectors at the segment boundary ranks
  (value cuts == rank cuts) and the per-device numpy counts;
* ``dropless_moe_ffn`` is bit-exact with the dense all-experts reference
  under uniform routing, all-tokens-to-one-expert, and p-hot-experts
  adversarial skew — with zero drops at the default capacity;
* exact lengths-sideband accounting: received lengths equal the planned
  per-source counts from the cut matrix, and the grouped-GEMM group
  sizes sum to the global assignment count;
* an undersized explicit capacity produces *exactly* the predicted
  truncation counts (detected, never silent);
* bitwise determinism across two independent jit compilations;
* HLO: the ragged exchange path contains no full-N *value* all-gather —
  only O(p E) int32 metadata — and moves payload via all_to_all.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map
from repro.distributed import (
    distributed_co_rank_kway,
    distributed_segment_cuts,
    dropless_moe_ffn,
)
from repro.launch.hlo_stats import collective_op_sizes

E, K, D, FF = 16, 4, 16, 32
T_LOC = 32  # tokens per device


def _routings(p, t, rng):
    e_per = E // p
    hot = np.arange(p) * e_per
    return [
        ("uniform", rng.integers(0, E, (t, K))),
        ("one-expert", np.full((t, K), 5)),
        ("p-hot", hot[rng.integers(0, p, (t, K))]),
    ]


def check_segment_cuts(mesh, p, rng):
    """Value-keyed cuts == rank-keyed co-rank cuts == numpy counts."""
    w = 64
    runs = np.sort(rng.integers(0, E, (p, w)), axis=1).astype(np.int32)

    def body(run_shard):
        run = run_shard.reshape(-1)
        cuts = distributed_segment_cuts(run, E, "x")  # (p, E+1)
        # boundary ranks of every segment, from the cuts themselves
        ranks = cuts.sum(axis=0)  # (E+1,)
        rank_cuts = distributed_co_rank_kway(ranks, run, "x")  # (E+1, p)
        return jnp.stack([cuts, rank_cuts.T])[None]

    fn = shard_map(body, mesh=mesh, in_specs=(P("x"),), out_specs=P("x"))
    out = np.asarray(jax.jit(fn)(jnp.asarray(runs)))  # (p, 2, p, E+1)
    want = np.stack(
        [np.searchsorted(runs[d], np.arange(E + 1)) for d in range(p)]
    )
    for d in range(p):
        np.testing.assert_array_equal(out[d, 0], want, err_msg="vs numpy")
        np.testing.assert_array_equal(
            out[d, 0], out[d, 1],
            err_msg="value cuts must equal co-rank cuts at boundary ranks",
        )
    print("segment cuts == co-rank cuts at boundary ranks == numpy: OK")


def _build(p, rng):
    wg = jnp.asarray(rng.standard_normal((E, D, FF)), jnp.float32)
    wu = jnp.asarray(rng.standard_normal((E, D, FF)), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((E, FF, D)), jnp.float32)
    t = p * T_LOC
    xt = jnp.asarray(rng.standard_normal((t, D)), jnp.float32)
    w = jnp.asarray(rng.random((t, K)), jnp.float32)
    return xt, w, wg, wu, wd


def _dense_reference(xt, experts, w, wg, wu, wd):
    """All-experts reference, same reduction order as the combine."""
    t = xt.shape[0]
    ys = []
    for e in range(E):
        g = xt @ wg[e]
        u = xt @ wu[e]
        ys.append((jax.nn.silu(g) * u) @ wd[e])
    ys = jnp.stack(ys)
    contrib = jnp.stack(
        [ys[experts[:, c], jnp.arange(t)] * w[:, c, None] for c in range(K)],
        axis=1,
    )
    return np.asarray(contrib.sum(axis=1))


def _sharded_ffn(mesh, capacity=None):
    def fn(xt_l, e_l, w_l, wg, wu, wd):
        out, plan = dropless_moe_ffn(
            xt_l, e_l, w_l, wg, wu, wd, E, "x", capacity
        )
        drops = (plan.planned - plan.recv_lengths)[None]  # (1, p)
        return out, drops, plan.group_sizes[None], plan.recv_lengths[None]

    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(P("x"), P("x"), P("x"), P("x"), P("x"), P("x")),
        out_specs=(P("x"), P("x"), P("x"), P("x")),
    )


def check_dropless_scenarios(mesh, p, rng):
    """Bit-exact vs dense reference, zero drops, exact accounting."""
    xt, w, wg, wu, wd = _build(p, rng)
    t = p * T_LOC
    fn = jax.jit(_sharded_ffn(mesh))
    for name, experts_np in _routings(p, t, rng):
        experts = jnp.asarray(experts_np, jnp.int32)
        out, drops, gs, rl = fn(xt, experts, w, wg, wu, wd)
        out, drops, gs, rl = map(np.asarray, (out, drops, gs, rl))
        want = _dense_reference(xt, experts, w, wg, wu, wd)
        np.testing.assert_array_equal(
            out, want, err_msg=f"{name}: dropless != dense reference"
        )
        assert drops.sum() == 0, f"{name}: dropped {drops.sum()} tokens"
        assert gs.sum() == t * K, (
            f"{name}: group sizes account for {gs.sum()} != {t * K}"
        )
        # exact sideband accounting: per-device received totals equal the
        # per-device owned-expert assignment counts
        e_per = E // p
        counts = np.bincount(experts_np.reshape(-1), minlength=E)
        for dev in range(p):
            owned = counts[dev * e_per : (dev + 1) * e_per].sum()
            assert rl[dev].sum() == owned, (
                f"{name}: device {dev} sideband {rl[dev].sum()} != {owned}"
            )
        print(f"dropless [{name}]: bit-exact, zero drops, exact sideband: OK")


def check_capacity_truncation(mesh, p, rng):
    """An undersized capacity drops exactly the predicted overflow."""
    t = p * T_LOC
    xt, w, wg, wu, wd = _build(p, rng)
    experts_np = np.full((t, K), 5)  # all -> expert 5 (owner dev 2)
    cap = 16  # each (sender, owner) segment is T_LOC*K = 128 > 16
    fn = jax.jit(_sharded_ffn(mesh, capacity=cap))
    out, drops, gs, rl = map(
        np.asarray, fn(xt, jnp.asarray(experts_np, jnp.int32), w, wg, wu, wd)
    )
    e_per = E // p
    owner = 5 // e_per
    # every sender's segment to `owner` is T_LOC*K, truncated to cap
    want_drops = p * (T_LOC * K - cap)
    assert drops.sum() == want_drops, (drops.sum(), want_drops)
    assert drops[owner].sum() == want_drops  # all drops land on the owner
    assert gs.sum() == p * cap  # survivors = p segments of cap rows
    assert np.isfinite(out).all()
    print(f"capacity truncation exact accounting ({want_drops} drops): OK")


def check_determinism(mesh, p, rng):
    """Two independent jit compilations produce bitwise-identical output."""
    t = p * T_LOC
    xt, w, wg, wu, wd = _build(p, rng)
    experts = jnp.asarray(rng.integers(0, E, (t, K)), jnp.int32)
    f1 = jax.jit(_sharded_ffn(mesh))
    # a distinct jaxpr (harmless extra op) forces a second compilation
    base = _sharded_ffn(mesh)
    f2 = jax.jit(lambda *a: base(*a)[0] * 1.0)
    o1 = np.asarray(f1(xt, experts, w, wg, wu, wd)[0])
    o2 = np.asarray(f2(xt, experts, w, wg, wu, wd))
    np.testing.assert_array_equal(o1, o2)
    print("bitwise determinism across two jit compilations: OK")


def check_hlo_no_value_allgather(mesh, p):
    """The ragged exchange path never all-gathers N-sized values."""
    t = p * T_LOC
    n_vals = t * K * D  # total routed activation elements

    fn = jax.jit(_sharded_ffn(mesh))
    txt = (
        fn.lower(
            jax.ShapeDtypeStruct((t, D), jnp.float32),
            jax.ShapeDtypeStruct((t, K), jnp.int32),
            jax.ShapeDtypeStruct((t, K), jnp.float32),
            jax.ShapeDtypeStruct((E, D, FF), jnp.float32),
            jax.ShapeDtypeStruct((E, D, FF), jnp.float32),
            jax.ShapeDtypeStruct((E, FF, D), jnp.float32),
        )
        .compile()
        .as_text()
    )
    ag = collective_op_sizes(txt, "all-gather")
    assert all(el < t * D for _, el in ag), (
        f"dropless path must not all-gather value-sized arrays: {ag}"
    )
    # the only all-gather is the O(p * E) int32 cut matrix
    assert all(el <= p * (E + 1) for _, el in ag), ag
    a2a = collective_op_sizes(txt, "all-to-all")
    assert a2a, "dropless path must move payload via all_to_all"
    assert max(el for _, el in a2a) <= p * (T_LOC * K) * D, a2a
    print(
        f"HLO: dropless all-gathers {ag} (metadata only, < N*d={n_vals}): OK"
    )


def main():
    devs = jax.devices()
    assert len(devs) == 8, devs
    p = 8
    mesh = Mesh(np.array(devs), ("x",))
    rng = np.random.default_rng(0)

    check_segment_cuts(mesh, p, rng)
    check_dropless_scenarios(mesh, p, rng)
    check_capacity_truncation(mesh, p, rng)
    check_determinism(mesh, p, rng)
    check_hlo_no_value_allgather(mesh, p)
    print("ALL OK")


if __name__ == "__main__":
    main()
