"""Model-math tests: SSD vs naive recurrence, flash attention (fwd + custom
VJP) vs dense softmax, MLA absorbed decode vs decompressed attention."""

import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.attention import flash_attention, make_flash_attention_vjp
from repro.models.ssm import ssd_chunked


def naive_gqa(q, k, v, causal=True):
    b, s, h, hd = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    qg = q.reshape(b, s, n_kv, g, hd) / math.sqrt(hd)
    sc = jnp.einsum("bqcgd,bkcd->bcgqk", qg, k).astype(jnp.float32)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        sc = jnp.where(mask[None, None, None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, -1).astype(q.dtype)
    o = jnp.einsum("bcgqk,bkcd->bqcgd", p, v)
    return o.reshape(b, s, h, v.shape[3])


@pytest.mark.parametrize("qc,kc", [(16, 16), (64, 32), (128, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_forward_matches_dense(qc, kc, causal):
    rng = np.random.default_rng(0)
    b, s, h, n_kv, hd = 2, 128, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, n_kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, n_kv, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    want = naive_gqa(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_causal_skip_identical():
    rng = np.random.default_rng(1)
    b, s, h, n_kv, hd = 1, 256, 2, 1, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, n_kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, n_kv, hd)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    b_ = flash_attention(
        q, k, v, causal=True, q_chunk=64, kv_chunk=64, causal_skip=True
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)


def test_flash_custom_vjp_grads():
    rng = np.random.default_rng(2)
    b, s, h, n_kv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, n_kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, n_kv, hd)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    fa = make_flash_attention_vjp(causal=True, q_chunk=16, kv_chunk=16)
    g_ref = jax.grad(lambda *a: jnp.sum(naive_gqa(*a) * w), argnums=(0, 1, 2))(
        q, k, v
    )
    g_fa = jax.grad(lambda *a: jnp.sum(fa(*a) * w), argnums=(0, 1, 2))(q, k, v)
    for name, (a, b_) in zip("qkv", zip(g_ref, g_fa)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b_), atol=5e-5,
            err_msg=f"d{name} mismatch",
        )


def _naive_ssd(x, dt, b, c, a_log, d_skip):
    bt, s, h, p = x.shape
    g = b.shape[2]
    hg = h // g
    a = -np.exp(a_log)
    H = np.zeros((bt, h, p, b.shape[3]))
    ys = np.zeros((bt, s, h, p))
    for t in range(s):
        for hi in range(h):
            gi = hi // hg
            dec = np.exp(dt[:, t, hi] * a[hi])
            H[:, hi] = H[:, hi] * dec[:, None, None] + dt[:, t, hi][
                :, None, None
            ] * np.einsum("bp,bn->bpn", x[:, t, hi], b[:, t, gi])
            ys[:, t, hi] = (
                np.einsum("bpn,bn->bp", H[:, hi], c[:, t, gi])
                + d_skip[hi] * x[:, t, hi]
            )
    return ys, H


@pytest.mark.parametrize("chunk", [1, 4, 16])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_chunked_matches_recurrence(chunk, g):
    rng = np.random.default_rng(3)
    bt, s, h, p, n = 2, 16, 4, 8, 5
    x = rng.standard_normal((bt, s, h, p)).astype(np.float32)
    dt = rng.uniform(0.01, 0.5, (bt, s, h)).astype(np.float32)
    b = rng.standard_normal((bt, s, g, n)).astype(np.float32)
    c = rng.standard_normal((bt, s, g, n)).astype(np.float32)
    a_log = rng.uniform(-1, 1, (h,)).astype(np.float32)
    d_skip = rng.standard_normal((h,)).astype(np.float32)
    y, hl = ssd_chunked(
        jnp.asarray(x), jnp.asarray(dt), jnp.asarray(b), jnp.asarray(c),
        jnp.asarray(a_log), jnp.asarray(d_skip), {}, chunk=chunk,
    )
    want_y, want_h = _naive_ssd(x, dt, b, c, a_log, d_skip)
    np.testing.assert_allclose(np.asarray(y), want_y, atol=2e-5)
    np.testing.assert_allclose(np.asarray(hl), want_h, atol=2e-5)


def test_ssd_state_continuation():
    """Splitting a sequence and carrying h0 must match the full pass —
    the property serve-time decode relies on."""
    rng = np.random.default_rng(4)
    bt, s, h, p, g, n = 1, 32, 2, 4, 1, 3
    args = lambda sl: (
        jnp.asarray(rng2.standard_normal((bt, sl, h, p)), jnp.float32),
    )
    rng2 = rng
    x = rng.standard_normal((bt, s, h, p)).astype(np.float32)
    dt = rng.uniform(0.05, 0.4, (bt, s, h)).astype(np.float32)
    b = rng.standard_normal((bt, s, g, n)).astype(np.float32)
    c = rng.standard_normal((bt, s, g, n)).astype(np.float32)
    a_log = np.zeros((h,), np.float32)
    d = np.zeros((h,), np.float32)
    full, _ = ssd_chunked(*map(jnp.asarray, (x, dt, b, c, a_log, d)), {},
                          chunk=8)
    y1, h1 = ssd_chunked(
        *map(jnp.asarray, (x[:, :16], dt[:, :16], b[:, :16], c[:, :16],
                           a_log, d)), {}, chunk=8,
    )
    y2, _ = ssd_chunked(
        *map(jnp.asarray, (x[:, 16:], dt[:, 16:], b[:, 16:], c[:, 16:],
                           a_log, d)), {}, chunk=8, h0=h1,
    )
    np.testing.assert_allclose(
        np.concatenate([np.asarray(y1), np.asarray(y2)], 1),
        np.asarray(full), atol=2e-5,
    )


def test_mla_absorbed_decode_matches_train():
    """The absorbed decode path must equal decompress-then-attend on the
    same single step (teacher forcing, step t attends cache 0..t)."""
    import dataclasses

    from repro.configs.registry import ARCHS, smoke_config
    from repro.models.transformer import (
        decode_step, hidden_states, init_cache, init_params, _unembed_table,
    )

    cfg = dataclasses.replace(smoke_config(ARCHS["deepseek-v3-671b"]))
    params, _ = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(5)
    b, s = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    h = hidden_states(cfg, params, toks)
    logits_train = jnp.einsum(
        "bsd,vd->bsv", h, _unembed_table(cfg, params).astype(h.dtype)
    ).astype(jnp.float32)

    cache = init_cache(cfg, b, s)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    outs = []
    for t in range(s):
        lg, cache = step(params, cache, toks[:, t : t + 1])
        outs.append(lg)
    logits_decode = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_decode), np.asarray(logits_train),
        rtol=0.15, atol=0.2,  # bf16 path differences
    )
