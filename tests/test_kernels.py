"""Pallas kernel validation: sweep shapes/dtypes, compare to pure-jnp oracle.

Kernels run in interpret mode (CPU container); the kernel body is executed
exactly as written, so correctness here validates the TPU program logic.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from _prop import given, settings, st

from repro.kernels.merge import merge_kway_pallas, merge_pallas
from repro.kernels.ref import merge_np, merge_ref


def rand_sorted(rng, size, dtype, lo=-1000, hi=1000):
    if np.issubdtype(dtype, np.integer):
        x = rng.integers(lo, hi, size).astype(dtype)
    else:
        x = rng.standard_normal(size).astype(np.float32) * 100
        x = x.astype(dtype)
    return np.sort(x)


@pytest.mark.parametrize("dtype", [np.int32, np.float32, "bfloat16"])
@pytest.mark.parametrize(
    "m,n",
    [(1, 1), (1, 4096), (4096, 1), (1000, 1000), (777, 3333), (4096, 4096)],
)
@pytest.mark.parametrize("tile", [128, 512])
def test_merge_kernel_sweep(dtype, m, n, tile):
    rng = np.random.default_rng(abs(hash((str(dtype), m, n, tile))) % 2**32)
    if dtype == "bfloat16":
        # small integer-valued floats: exact in bf16 (8-bit mantissa),
        # avoids rounding-induced reorders vs the float32 oracle
        a = np.sort(rng.integers(-250, 250, m)).astype(np.float32)
        b = np.sort(rng.integers(-250, 250, n)).astype(np.float32)
        a_j, b_j = jnp.asarray(a, jnp.bfloat16), jnp.asarray(b, jnp.bfloat16)
        got = np.asarray(merge_pallas(a_j, b_j, tile=tile)).astype(np.float32)
        want = merge_np(a, b)
    else:
        a, b = rand_sorted(rng, m, dtype), rand_sorted(rng, n, dtype)
        got = np.asarray(merge_pallas(jnp.asarray(a), jnp.asarray(b), tile=tile))
        want = merge_np(a, b)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_merge_kernel_matches_jnp_ref():
    rng = np.random.default_rng(7)
    a = rand_sorted(rng, 2048, np.float32)
    b = rand_sorted(rng, 1024, np.float32)
    got = merge_pallas(jnp.asarray(a), jnp.asarray(b), tile=256)
    want = merge_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_merge_kernel_stability_tagged():
    """Ties: every A element must precede every equal B element.

    Tag parity trick: keys doubled, A even / B odd, so origin and order are
    recoverable from the merged values.
    """
    rng = np.random.default_rng(11)
    a = np.sort(rng.integers(0, 8, 1500)).astype(np.int32)
    b = np.sort(rng.integers(0, 8, 700)).astype(np.int32)
    got = np.asarray(
        merge_pallas(jnp.asarray(a * 2), jnp.asarray(b * 2 + 1), tile=128)
    )
    keys, origin = got // 2, got % 2
    # grouped by key, origin must be all-0 then all-1
    for v in np.unique(keys):
        seg = origin[keys == v]
        assert not np.any(np.diff(seg) < 0), f"instability at key {v}"
    np.testing.assert_array_equal(np.sort(keys, kind="stable"), keys)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(1, 600),
    st.integers(1, 600),
    st.sampled_from([128, 256]),
    st.integers(0, 2**31 - 1),
)
def test_merge_kernel_property(m, n, tile, seed):
    rng = np.random.default_rng(seed)
    a = np.sort(rng.integers(-20, 20, m)).astype(np.int32)
    b = np.sort(rng.integers(-20, 20, n)).astype(np.int32)
    got = np.asarray(merge_pallas(jnp.asarray(a), jnp.asarray(b), tile=tile))
    np.testing.assert_array_equal(got, merge_np(a, b))


def test_merge_kernel_adversarial_skew():
    """All of A below all of B — worst case for equidistant partitions,
    exactly balanced for co-ranking."""
    a = jnp.arange(0, 3000, dtype=jnp.int32)
    b = jnp.arange(3000, 5000, dtype=jnp.int32)
    got = np.asarray(merge_pallas(a, b, tile=256))
    np.testing.assert_array_equal(got, np.arange(5000, dtype=np.int32))


# --- k-way tile kernel: payload + ragged-lengths extension ------------------


@pytest.mark.parametrize("k,w,tile", [(2, 256, 128), (4, 512, 128),
                                      (8, 256, 256)])
def test_kway_kernel_payload_rides_stable_permutation(k, w, tile):
    """(key, payload) pairs through the tile kernel: payload must follow
    the exact stable permutation (run index breaks ties), checked on
    duplicate-heavy keys where any instability shuffles payloads."""
    rng = np.random.default_rng(k * 1000 + w + tile)
    runs = np.sort(rng.integers(0, 7, (k, w)).astype(np.int32), axis=1)
    vals = np.arange(k * w, dtype=np.int32).reshape(k, w)
    gk, gv = merge_kway_pallas(jnp.asarray(runs), jnp.asarray(vals),
                               tile=tile)
    order = np.argsort(runs.reshape(-1), kind="stable")
    np.testing.assert_array_equal(np.asarray(gk), runs.reshape(-1)[order])
    np.testing.assert_array_equal(np.asarray(gv), vals.reshape(-1)[order])


def test_kway_kernel_ragged_lengths_with_dtype_max():
    """Ragged runs whose padding collides with real INT32_MAX keys: the
    lengths sideband (co-rank clamping), not sentinel ordering, must keep
    the merged prefix exact."""
    hi = np.iinfo(np.int32).max
    rng = np.random.default_rng(99)
    k, w = 4, 256
    lengths = np.array([256, 0, 100, 31], np.int32)
    runs = np.full((k, w), hi, np.int32)
    vals = np.zeros((k, w), np.int32)
    parts_k, parts_v = [], []
    nxt = 0
    for q in range(k):
        seg = np.sort(
            rng.choice(np.array([hi, hi - 1, 3, -9], np.int32), lengths[q])
        )
        runs[q, : lengths[q]] = seg
        vals[q, : lengths[q]] = np.arange(nxt, nxt + lengths[q])
        parts_k.append(seg)
        parts_v.append(vals[q, : lengths[q]].copy())
        nxt += int(lengths[q])
    ks = np.concatenate(parts_k)
    order = np.argsort(ks, kind="stable")
    total = int(lengths.sum())
    gk, gv = merge_kway_pallas(
        jnp.asarray(runs), jnp.asarray(vals),
        lengths=jnp.asarray(lengths), tile=128,
    )
    np.testing.assert_array_equal(np.asarray(gk)[:total], ks[order])
    np.testing.assert_array_equal(
        np.asarray(gv)[:total], np.concatenate(parts_v)[order]
    )
