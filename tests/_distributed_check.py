"""Multi-device checks, run in a subprocess with 8 fake host devices.

Invoked by tests/test_distributed.py; exits nonzero on any failure.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.compat import shard_map

from repro.distributed.api import distributed_merge, distributed_sort
from repro.distributed.splitters import distributed_co_rank
from repro.core.corank import co_rank


def main():
    devs = jax.devices()
    assert len(devs) == 8, devs
    mesh = Mesh(np.array(devs), ("x",))
    p = 8
    rng = np.random.default_rng(0)

    # --- distributed_merge (allgather strategy) -------------------------
    m = n = 64 * p
    a = np.sort(rng.integers(0, 1000, m)).astype(np.int32)
    b = np.sort(rng.integers(0, 1000, n)).astype(np.int32)

    fn = shard_map(
        lambda a_, b_: distributed_merge(a_, b_, "x"),
        mesh=mesh,
        in_specs=(P("x"), P("x")),
        out_specs=P("x"),
    )
    got = np.asarray(jax.jit(fn)(jnp.asarray(a), jnp.asarray(b)))
    want = np.sort(np.concatenate([a, b]), kind="stable")
    np.testing.assert_array_equal(got, want)
    print("distributed_merge allgather: OK")

    # --- distributed co-rank vs single-device co_rank -------------------
    def cr(a_, b_):
        r = jax.lax.axis_index("x")
        i = (r * 97) % (m + n)
        j, k = distributed_co_rank(i, a_, b_, "x")
        return jnp.stack([j, k])[None]

    fn2 = shard_map(
        cr, mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x")
    )
    jk = np.asarray(jax.jit(fn2)(jnp.asarray(a), jnp.asarray(b)))
    for r in range(p):
        i = (r * 97) % (m + n)
        res = co_rank(i, jnp.asarray(a), jnp.asarray(b))
        assert jk[r, 0] == int(res.j) and jk[r, 1] == int(res.k), (
            r, i, jk[r], int(res.j), int(res.k),
        )
    print("distributed_co_rank: OK")

    # --- merge with distributed co-rank partition (strategy switch) ------
    fn3 = shard_map(
        lambda a_, b_: distributed_merge(a_, b_, "x", strategy="corank"),
        mesh=mesh,
        in_specs=(P("x"), P("x")),
        out_specs=P("x"),
    )
    got3 = np.asarray(jax.jit(fn3)(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got3, want)
    print("distributed_merge strategy=corank: OK")

    # --- distributed_sort -------------------------------------------------
    x = rng.integers(-50, 50, 128 * p).astype(np.int32)
    fn4 = shard_map(
        lambda x_: distributed_sort(x_, "x"),
        mesh=mesh,
        in_specs=(P("x"),),
        out_specs=P("x"),
    )
    got4 = np.asarray(jax.jit(fn4)(jnp.asarray(x)))
    np.testing.assert_array_equal(got4, np.sort(x, kind="stable"))
    print("distributed_sort: OK")

    # --- collective stats: count bytes moved (for DESIGN/EXPERIMENTS) ----
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((m,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
    )
    txt = lowered.compile().as_text()
    n_ag = txt.count("all-gather")
    print(f"merge collectives: all-gather ops in HLO = {n_ag}")
    print("ALL OK")


if __name__ == "__main__":
    main()
