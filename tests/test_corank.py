"""Unit + property tests for the co-rank algorithm (paper Algorithm 1)."""

import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _prop import given, settings, st

from repro.core import co_rank, co_rank_batch


def lemma_conditions_hold(a, b, i, j, k):
    """Check Lemma 1's two conditions directly."""
    m, n = len(a), len(b)
    if j + k != i:
        return False
    c1 = (j == 0) or (k >= n) or (a[j - 1] <= b[k])
    c2 = (k == 0) or (j >= m) or (b[k - 1] < a[j])
    return bool(c1 and c2)


def oracle_corank(a, b, i):
    """Reference co-rank: simulate a stable merge and count sources."""
    m, n = len(a), len(b)
    j = k = 0
    while j + k < i:
        if j < m and (k >= n or a[j] <= b[k]):
            j += 1
        else:
            k += 1
    return j, k


def _as_np(x):
    return np.asarray(x)


@pytest.mark.parametrize("m,n", [(8, 8), (5, 13), (1, 64), (64, 1), (17, 3)])
def test_corank_matches_oracle_exhaustive(m, n):
    rng = np.random.default_rng(0)
    a = np.sort(rng.integers(0, 10, size=m)).astype(np.int32)
    b = np.sort(rng.integers(0, 10, size=n)).astype(np.int32)
    for i in range(m + n + 1):
        res = co_rank(i, jnp.asarray(a), jnp.asarray(b))
        j, k = int(res.j), int(res.k)
        assert (j, k) == oracle_corank(a, b, i), (m, n, i)
        assert lemma_conditions_hold(a, b, i, j, k)


def test_corank_iteration_bound():
    """Proposition 1: iterations <= ceil(log2 min(m, n, i, m+n-i))."""
    rng = np.random.default_rng(1)
    for m, n in [(33, 77), (128, 128), (1000, 10), (3, 500)]:
        a = np.sort(rng.standard_normal(m)).astype(np.float32)
        b = np.sort(rng.standard_normal(n)).astype(np.float32)
        res = co_rank_batch(
            jnp.arange(m + n + 1), jnp.asarray(a), jnp.asarray(b)
        )
        for i in range(m + n + 1):
            lim = min(m, n, max(i, 1), max(m + n - i, 1))
            bound = math.ceil(math.log2(lim)) if lim > 1 else 1
            assert int(res.iterations[i]) <= max(bound, 1) + 1, (
                m, n, i, int(res.iterations[i]), bound,
            )


def test_corank_duplicates_stability():
    """With heavy duplication the co-rank must still split stably:
    all equal A-elements in the prefix before any equal B-element."""
    a = np.zeros(16, np.int32)
    b = np.zeros(16, np.int32)
    for i in range(33):
        res = co_rank(i, jnp.asarray(a), jnp.asarray(b))
        j, k = int(res.j), int(res.k)
        # Stable merge of all-equal keys = all of A then all of B.
        assert j == min(i, 16) and k == i - j


@settings(max_examples=200, deadline=None)
@given(
    st.lists(st.integers(-50, 50), min_size=1, max_size=70),
    st.lists(st.integers(-50, 50), min_size=1, max_size=70),
    st.data(),
)
def test_corank_property(xs, ys, data):
    a = np.sort(np.asarray(xs, np.int32))
    b = np.sort(np.asarray(ys, np.int32))
    i = data.draw(st.integers(0, len(a) + len(b)))
    res = co_rank(i, jnp.asarray(a), jnp.asarray(b))
    j, k = int(res.j), int(res.k)
    assert (j, k) == oracle_corank(a, b, i)
    assert lemma_conditions_hold(a, b, i, j, k)


def test_corank_batch_vmap_consistency():
    rng = np.random.default_rng(2)
    a = np.sort(rng.integers(0, 100, 257)).astype(np.int32)
    b = np.sort(rng.integers(0, 100, 129)).astype(np.int32)
    ranks = jnp.asarray([0, 1, 57, 129, 257, 386], jnp.int32)
    batch = co_rank_batch(ranks, jnp.asarray(a), jnp.asarray(b))
    for t, i in enumerate([0, 1, 57, 129, 257, 386]):
        single = co_rank(i, jnp.asarray(a), jnp.asarray(b))
        assert int(batch.j[t]) == int(single.j)
        assert int(batch.k[t]) == int(single.k)
