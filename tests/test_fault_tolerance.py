"""Fault-tolerance tests: kill/restart training, elastic mesh re-sharding."""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run_train(ckpt_dir, steps, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "granite-3-2b", "--smoke",
        "--steps", str(steps), "--batch", "2", "--seq", "64",
        "--ckpt-dir", str(ckpt_dir), "--ckpt-every", "5",
        "--log-every", "5", *extra,
    ]
    return subprocess.run(
        cmd, capture_output=True, text=True, env=env, timeout=900
    )


def test_restart_resumes_from_checkpoint(tmp_path):
    """Train 10 steps, 'crash', relaunch to 20: the second run must resume
    from step 10, not step 0, and reach the same final state as an
    uninterrupted run (deterministic data + optimizer)."""
    d1 = tmp_path / "interrupted"
    p = _run_train(d1, 10)
    assert p.returncode == 0, p.stderr[-2000:]
    p = _run_train(d1, 20)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "resumed from step 10" in p.stdout, p.stdout

    d2 = tmp_path / "straight"
    p2 = _run_train(d2, 20)
    assert p2.returncode == 0, p2.stderr[-2000:]

    # same final checkpoint contents (bitwise: same data, same updates)
    import json

    m1 = json.load(open(d1 / "step_00000020" / "manifest.json"))
    m2 = json.load(open(d2 / "step_00000020" / "manifest.json"))
    f1 = {e["name"]: e["file"] for e in m1["leaves"]}
    f2 = {e["name"]: e["file"] for e in m2["leaves"]}
    assert f1.keys() == f2.keys()
    worst = 0.0
    for name in f1:
        a = np.load(d1 / "step_00000020" / f1[name])
        b = np.load(d2 / "step_00000020" / f2[name])
        if a.dtype.kind in "fiu" and a.size:
            worst = max(
                worst,
                float(np.max(np.abs(a.astype(np.float64) - b.astype(np.float64)))),
            )
    assert worst < 1e-4, f"resume diverged from straight run by {worst}"


def test_torn_checkpoint_ignored(tmp_path):
    """A .tmp directory (simulated crash mid-write) must not be restored."""
    d = tmp_path / "ckpt"
    p = _run_train(d, 5)
    assert p.returncode == 0, p.stderr[-2000:]
    os.makedirs(d / "step_00000099.tmp")
    from repro.checkpoint.checkpointer import latest_step

    assert latest_step(str(d)) == 5


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpointer import save_checkpoint, restore_checkpoint

devs = np.array(jax.devices())
mesh1 = Mesh(devs.reshape(4, 2), ("data", "model"))
mesh2 = Mesh(devs.reshape(2, 4), ("data", "model"))

spec = {"w": P("data", "model"), "b": P("model")}
state = {
    "w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
    "b": jnp.arange(8, dtype=jnp.float32),
}
state = {
    k: jax.device_put(v, NamedSharding(mesh1, spec[k])) for k, v in state.items()
}
save_checkpoint("CKPT", 1, state, specs=spec)

like = jax.eval_shape(lambda: state)
restored = restore_checkpoint("CKPT", 1, like, mesh=mesh2)
for k in state:
    np.testing.assert_array_equal(np.asarray(restored[k]), np.asarray(state[k]))
    sh = restored[k].sharding
    assert sh.mesh.devices.shape == mesh2.devices.shape, sh
print("ELASTIC OK")
"""


def test_elastic_mesh_restore(tmp_path):
    """Save sharded on a 4x2 mesh, restore onto 2x4 — same values, new
    sharding (the shrink/grow path of DESIGN.md §8)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    p = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
        cwd=str(tmp_path),
    )
    assert p.returncode == 0, p.stderr[-2000:]
    assert "ELASTIC OK" in p.stdout
