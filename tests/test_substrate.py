"""Substrate tests: optimizer, train step, data pipeline, checkpoint, sampling."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, smoke_config
from repro.checkpoint.checkpointer import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data.pipeline import DataConfig, batches, bucket_by_length, pack_documents
from repro.models.transformer import init_params
from repro.train.optimizer import AdamWState, adamw_init, adamw_update, cosine_schedule
from repro.train.train_step import build_train_step


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, gnorm = adamw_update(
            grads, state, params, lr=0.05, weight_decay=0.0
        )
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_cosine_schedule_shape():
    s = cosine_schedule(jnp.int32(0), peak_lr=1.0, warmup=10, total=100)
    assert float(s) == 0.0
    s = cosine_schedule(jnp.int32(10), peak_lr=1.0, warmup=10, total=100)
    assert abs(float(s) - 1.0) < 1e-6
    s_end = cosine_schedule(jnp.int32(100), peak_lr=1.0, warmup=10, total=100)
    assert float(s_end) < 0.11


def test_train_step_descends_loss():
    import dataclasses

    cfg = dataclasses.replace(
        smoke_config(ARCHS["granite-3-2b"]), learning_rate=1e-2
    )
    params, _ = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    step_fn = jax.jit(build_train_step(cfg, total_steps=50, warmup=1))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32),
        "mask": jnp.ones((4, 32), jnp.float32),
    }
    losses = []
    for i in range(8):
        params, opt, metrics = step_fn(params, opt, batch, jnp.int32(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses  # memorising a fixed batch


def test_grad_accum_equivalence():
    """accum=2 must match accum=1 on the same global batch (linearity)."""
    import dataclasses

    cfg0 = smoke_config(ARCHS["qwen3-0.6b"])
    cfg2 = dataclasses.replace(cfg0, grad_accum=2)
    params, _ = init_params(cfg0, jax.random.key(1))
    opt = adamw_init(params)
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg0.vocab, (4, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg0.vocab, (4, 32)), jnp.int32),
        "mask": jnp.ones((4, 32), jnp.float32),
    }
    p1, _, m1 = jax.jit(build_train_step(cfg0))(params, opt, batch, jnp.int32(0))
    p2, _, m2 = jax.jit(build_train_step(cfg2))(params, opt, batch, jnp.int32(0))
    np.testing.assert_allclose(
        float(m1["loss"]), float(m2["loss"]), rtol=2e-2
    )
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        p1, p2,
    )
    assert max(jax.tree.leaves(d)) < 0.15


def test_pipeline_deterministic_and_resumable():
    dc = DataConfig(vocab=1000, seq_len=128, batch=4, seed=7)
    a = [next(batches(dc, start_step=s)) for s in range(3)]
    b0 = list(zip(range(3), batches(dc)))
    for s, (_, bb) in enumerate(b0):
        np.testing.assert_array_equal(
            np.asarray(a[s]["tokens"]), np.asarray(bb["tokens"])
        )
    # mask and labels align: label at masked position is next token
    bt = a[0]
    assert bt["tokens"].shape == (4, 128)
    assert float(jnp.mean(bt["mask"])) > 0.3


def test_pipeline_rank_disjoint():
    dc = DataConfig(vocab=1000, seq_len=64, batch=2, seed=3)
    b0 = next(batches(dc, rank=0, world=2))
    b1 = next(batches(dc, rank=1, world=2))
    assert not np.array_equal(np.asarray(b0["tokens"]), np.asarray(b1["tokens"]))


def test_bucket_by_length_stable():
    lens = np.asarray([5, 3, 5, 1, 3], np.int32)
    order = bucket_by_length(lens)
    np.testing.assert_array_equal(order, [3, 1, 4, 0, 2])


def test_checkpoint_roundtrip_atomic(tmp_path):
    state = {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "opt": {"m": jnp.ones((5,), jnp.bfloat16)},
        "step": jnp.int32(7),
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, state)
    save_checkpoint(d, 5, state)
    # torn checkpoint: tmp dir must be ignored
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert latest_step(d) == 5
    restored = restore_checkpoint(d, 5, jax.eval_shape(lambda: state))
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["opt"]["m"].dtype == np.dtype("bfloat16") or str(
        restored["opt"]["m"].dtype
    ) == "bfloat16"


def test_sampling_paths():
    from repro.serving.sampling import sample_greedy, sample_topk, sample_topp

    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((3, 128)), jnp.float32)
    g = sample_greedy(logits)
    np.testing.assert_array_equal(np.asarray(g), np.argmax(np.asarray(logits), -1))
    k = sample_topk(jax.random.key(0), logits, k=5)
    topk_sets = np.argsort(-np.asarray(logits), kind="stable")[:, :5]
    for i in range(3):
        assert int(k[i]) in topk_sets[i]
    p = sample_topp(jax.random.key(1), logits, p=0.5, k=16)
    assert p.shape == (3,)


def test_gradient_compression_unbiased():
    """int8 stochastic-rounding quantisation: E[q] == x, error bounded by
    the block scale; dequant(quantize) roundtrips within 1 LSB."""
    from repro.train.compress import dequantize_int8, quantize_int8

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 0.01, jnp.float32)
    qs = []
    for i in range(64):
        q, s, n = quantize_int8(x, jax.random.key(i))
        qs.append(np.asarray(dequantize_int8(q, s, n, x.shape, x.dtype)))
    qs = np.stack(qs)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    # single-draw error <= 1 LSB
    assert np.abs(qs[0] - np.asarray(x)).max() <= scale + 1e-9
    # averaging over draws converges toward x (unbiasedness)
    assert np.abs(qs.mean(0) - np.asarray(x)).max() < 0.35 * scale
