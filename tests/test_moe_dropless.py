"""Dropless expert-parallel MoE tests (subprocess: 8 fake host devices).

The main pytest process must keep a single device (smoke tests and
benchmarks expect it), so the 8-device runs happen in child processes —
mirroring tests/test_exchange.py.  ``scripts/verify.sh --moe`` runs this
file (and the fast semantic checks) explicitly.
"""

import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(script: str, *args: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / script), *args],
        capture_output=True,
        text=True,
        env=env,
        timeout=1800,
    )
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    return proc.stdout


@pytest.mark.slow  # subprocess run on 8 fake devices
def test_dropless_eight_devices():
    out = _run("_moe_dropless_check.py")
    assert "ALL OK" in out
    assert "HLO: dropless all-gathers" in out
