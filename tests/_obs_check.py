"""Observability acceptance checks, run in a subprocess with 8 fake host
devices.

Invoked by tests/test_obs.py; exits nonzero on any failure.  Covers the
telemetry subsystem's acceptance criteria end to end:

* an enabled 8-device exchange-strategy sort emits per-device counters:
  ``exchange.block_elements == N/p`` on every device (Proposition 2 over
  the wire), per-peer byte vectors that sum to exactly the block's bytes,
  and splitter round counts equal to their ``ceil(log2(w+1)) + 1`` bound;
* the runtime byte counters reconcile with the compile-time
  ``hlo.collectives`` report (``obs.attach_hlo_report`` /
  ``hlo_stats.collective_op_sizes``): received real + padding slots ==
  the all-to-all's HLO element count, exactly;
* ``corank.iterations`` records respect Proposition 1's
  ``ceil(log2 min(m, n)) + 1`` bound;
* dropless-MoE dispatch counters: zero ``moe.overflow`` at the safe
  default capacity, positive and exactly-accounted overflow under an
  undersized capacity on adversarially skewed routing;
* the JSONL sink round-trips: every line parses, and the parsed stream
  contains the Prop-1/Prop-2 evidence above;
* the disabled trace of the same sharded program contains no callback
  ``custom-call`` (zero-overhead-off on the distributed path too).
"""

import json
import os
import sys
import tempfile

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.core.compat import shard_map
from repro.core.corank import co_rank, prop1_bound
from repro.distributed import sharded_sort
from repro.distributed.moe import dropless_dispatch
from repro.launch.hlo_stats import collective_op_sizes

P_DEVICES = 8
W = 64  # run width per device; N = p * w
N = P_DEVICES * W
ITEMSIZE = 4  # int32 payloads throughout


def _sort_fn(mesh):
    return jax.jit(
        shard_map(
            lambda s: sharded_sort(s, "x", strategy="exchange"),
            mesh=mesh,
            in_specs=(P("x"),),
            out_specs=P("x"),
        )
    )


def _by_metric(recs, name):
    return [r for r in recs if r["metric"] == name]


def check_exchange_counters(mesh, rng):
    """Prop-2 and per-peer byte accounting from a live 8-device sort."""
    x = rng.integers(-99, 99, N).astype(np.int32)
    with obs.capture() as recs:
        out = np.asarray(_sort_fn(mesh)(jnp.asarray(x)))
        obs.flush()
        np.testing.assert_array_equal(out, np.sort(x, kind="stable"))

        block = _by_metric(recs, "exchange.block_elements")
        assert len(block) == P_DEVICES, block
        assert sorted(r["labels"]["device"] for r in block) == list(
            range(P_DEVICES)
        )
        for r in block:
            assert r["value"] == W, (
                f"Prop 2 violated: device {r['labels']['device']} received "
                f"{r['value']} real elements, want N/p = {W}"
            )

        peer = _by_metric(recs, "exchange.peer_bytes")
        assert len(peer) == P_DEVICES
        for r in peer:
            v = r["value"]
            assert len(v) == P_DEVICES and all(b >= 0 for b in v)
            assert sum(v) == W * ITEMSIZE, (
                f"per-peer bytes must sum to the block: {v}"
            )
        total_recv = sum(sum(r["value"]) for r in peer)
        assert total_recv == N * ITEMSIZE  # nothing lost, nothing doubled

        for r in _by_metric(recs, "exchange.send_lengths"):
            assert sum(r["value"]) == W  # every run fully distributed

        for r in _by_metric(recs, "exchange.padding_slots"):
            cap = r["labels"]["capacity"]
            assert r["value"] == P_DEVICES * cap - W

        rounds = _by_metric(recs, "splitters.kway_rounds")
        assert len(rounds) == P_DEVICES
        for r in rounds:
            assert r["value"] <= r["labels"]["bound"], r
            assert r["labels"]["w"] == W
    print("exchange counters (Prop 2, per-peer bytes, rounds): OK")


def check_hlo_reconciliation(mesh, rng):
    """Runtime byte counters == the compile-time collective schedule."""
    x = rng.integers(0, 50, N).astype(np.int32)
    with obs.capture() as recs:
        fn = _sort_fn(mesh)
        lowered = fn.lower(jax.ShapeDtypeStruct((N,), jnp.int32))
        stats = obs.attach_hlo_report("sharded_sort_exchange", lowered)
        txt = lowered.compile().as_text()
        np.asarray(fn(jnp.asarray(x)))
        obs.flush()

        a2a = collective_op_sizes(txt, "all-to-all")
        assert a2a, "exchange path must lower to all-to-all"
        slot_elems = max(el for _, el in a2a)

        # Every device's runtime accounting: real rows + padding slots
        # must equal the static slot matrix the compiler scheduled.
        blocks = _by_metric(recs, "exchange.block_elements")
        pads = _by_metric(recs, "exchange.padding_slots")
        for b, pd in zip(
            sorted(blocks, key=lambda r: r["labels"]["device"]),
            sorted(pads, key=lambda r: r["labels"]["device"]),
        ):
            assert b["value"] + pd["value"] == slot_elems, (
                f"runtime {b['value']} + {pd['value']} != "
                f"HLO slot elements {slot_elems}"
            )

        events = _by_metric(recs, "hlo.collectives")
        assert len(events) == 1 and events[0]["kind"] == "event"
        lbl = events[0]["labels"]
        assert lbl["entry"] == "sharded_sort_exchange"
        assert lbl["per_op_bytes"]["all-to-all"] >= slot_elems * ITEMSIZE
        assert stats["total_bytes"] == lbl["total_bytes"] > 0
    print(
        f"HLO reconciliation (slots={slot_elems} elems, "
        f"predicted {stats['per_op_bytes']['all-to-all']} a2a bytes): OK"
    )


def check_prop1_counters():
    """Recorded co-rank iteration counts stay within Proposition 1."""
    rng = np.random.default_rng(3)
    cases = [(8, 8), (1, 64), (64, 1), (37, 501), (256, 256)]
    with obs.capture() as recs:
        for m, n in cases:
            a = jnp.asarray(np.sort(rng.integers(-50, 50, m)), jnp.int32)
            b = jnp.asarray(np.sort(rng.integers(-50, 50, n)), jnp.int32)
            for i in (0, (m + n) // 2, m + n):
                co_rank(i, a, b)
        obs.flush()
        its = _by_metric(recs, "corank.iterations")
        assert len(its) == 3 * len(cases)
        for r in its:
            assert r["max"] <= r["labels"]["bound"] == prop1_bound(
                r["labels"]["m"], r["labels"]["n"]
            ), r
    print("Prop-1 iteration counters within bound: OK")


def check_moe_counters(mesh, rng):
    """Dropless dispatch: zero overflow at safe capacity, accounted
    overflow under an undersized one."""
    t, k, d, E = 16, 2, 8, 16

    def dispatch_fn(capacity):
        def body(xt, experts):
            plan = dropless_dispatch(
                xt[0], experts[0], E, "x", capacity=capacity
            )
            return plan.group_sizes[None]

        return jax.jit(
            shard_map(
                body,
                mesh=mesh,
                in_specs=(P("x"), P("x")),
                out_specs=P("x"),
            )
        )

    xt = jnp.asarray(
        rng.normal(size=(P_DEVICES, t, d)).astype(np.float32)
    )
    uniform = jnp.asarray(
        rng.integers(0, E, (P_DEVICES, t, k)).astype(np.int32)
    )
    with obs.capture() as recs:
        gs = np.asarray(dispatch_fn(None)(xt, uniform))
        obs.flush()
        assert gs.sum() == P_DEVICES * t * k  # no token dropped
        overflow = _by_metric(recs, "moe.overflow")
        assert len(overflow) == P_DEVICES
        assert all(r["value"] == 0 for r in overflow)
        assert obs.totals().get("moe.overflow", 0) == 0
        group = _by_metric(recs, "moe.group_sizes")
        assert sum(sum(r["value"]) for r in group) == P_DEVICES * t * k
        assert len(_by_metric(recs, "moe.routing_skew")) == P_DEVICES

    # Adversarial skew: every token routed to expert 0, so all p*t*k
    # assignments target device 0; an undersized per-peer capacity must
    # surface the truncation as exact overflow counts, never silently.
    skewed = jnp.zeros((P_DEVICES, t, k), jnp.int32)
    cap = 4
    with obs.capture() as recs:
        np.asarray(dispatch_fn(cap)(xt, skewed))
        obs.flush()
        dropped = obs.totals()["moe.overflow"]
        # device 0 receives min(cap, t*k) per source instead of t*k
        want = P_DEVICES * (t * k - cap)
        assert dropped == want, (dropped, want)
        per_source = {
            r["labels"]["device"]: r["value"]
            for r in _by_metric(recs, "moe.recv_per_source")
        }
        assert per_source[0] == [cap] * P_DEVICES
        assert all(
            v == [0] * P_DEVICES for dev, v in per_source.items() if dev
        )
    print(f"MoE counters (0 overflow safe, {want} accounted skewed): OK")


def check_jsonl_roundtrip(mesh, rng):
    """The acceptance artifact: an enabled run's metrics.jsonl parses and
    carries the Prop-1 / Prop-2 / per-peer-bytes evidence."""
    x = rng.integers(-5, 5, N).astype(np.int32)
    tmp = tempfile.mkdtemp(prefix="obs_check_")
    obs.enable(metrics_dir=tmp)
    try:
        obs.set_step(7)
        np.asarray(_sort_fn(mesh)(jnp.asarray(x)))
        a = jnp.asarray(np.sort(rng.integers(0, 9, 33)), jnp.int32)
        b = jnp.asarray(np.sort(rng.integers(0, 9, 90)), jnp.int32)
        co_rank(50, a, b)
        obs.flush()
    finally:
        obs.disable()

    path = os.path.join(tmp, "metrics.jsonl")
    with open(path, encoding="utf-8") as fh:
        recs = [json.loads(line) for line in fh if line.strip()]
    assert recs, f"no records in {path}"
    assert all(r.get("step") == 7 for r in recs if r["kind"] != "event")
    blocks = _by_metric(recs, "exchange.block_elements")
    assert len(blocks) == P_DEVICES
    assert all(r["value"] == W for r in blocks)
    for r in _by_metric(recs, "exchange.peer_bytes"):
        assert sum(r["value"]) == W * ITEMSIZE
    its = _by_metric(recs, "corank.iterations")
    assert its and all(r["max"] <= r["labels"]["bound"] for r in its)
    print(f"JSONL round-trip ({len(recs)} records at {path}): OK")


def check_disabled_hlo_clean(mesh):
    """Zero-overhead-off on the sharded program: no callback custom-call."""
    assert not obs.enabled()
    txt = (
        _sort_fn(mesh)
        .lower(jax.ShapeDtypeStruct((N,), jnp.int32))
        .compile()
        .as_text()
    )
    assert "custom-call" not in txt, (
        "disabled obs must leave no callback ops in the compiled HLO"
    )
    print("disabled HLO contains no callback custom-call: OK")


def main():
    devs = jax.devices()
    assert len(devs) == P_DEVICES, devs
    mesh = Mesh(np.array(devs), ("x",))
    rng = np.random.default_rng(0)

    check_exchange_counters(mesh, rng)
    check_hlo_reconciliation(mesh, rng)
    check_prop1_counters()
    check_moe_counters(mesh, rng)
    check_jsonl_roundtrip(mesh, rng)
    check_disabled_hlo_clean(mesh)
    print("ALL OK")


if __name__ == "__main__":
    main()
