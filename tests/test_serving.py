"""Serving harness tests: batched merge-based sampling bit-exactness,
scheduler/pool properties, engine determinism, and the e2e staggered-
arrival smoke decode (subprocess, @slow).

The batched samplers must be *bit-identical* to the per-request
references on exactly the inputs where float sorting goes wrong:
duplicate-heavy logits (ties must resolve to the lower token id),
``±inf`` entries, and dtype-max magnitudes — at every supported
tournament fan-out.
"""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _prop import given, settings, st

from repro import obs
from repro.configs.registry import ARCHS, smoke_config
from repro.core.topk import merge_topk
from repro.models.transformer import init_params
from repro.serving import (
    DecodeEngine,
    KVPool,
    Request,
    Scheduler,
    batched_topk,
    sample_topk,
    sample_topk_batched,
    sample_topp,
    sample_topp_batched,
)

REPO = pathlib.Path(__file__).resolve().parents[1]
FANOUTS = (2, 4, 16)
F32 = np.float32


# ---------------------------------------------------------------------------
# adversarial logit batteries
# ---------------------------------------------------------------------------


def _case(name: str, b: int = 5, n: int = 1000) -> np.ndarray:
    rng = np.random.default_rng(
        {"dups": 101, "inf": 202, "fmax": 303, "equal": 404}[name]
    )
    if name == "dups":  # heavy ties: 5 distinct values over 1000 tokens
        return rng.choice(
            np.asarray([-2.0, -1.0, 0.0, 1.0, 2.0], F32), size=(b, n)
        ).astype(F32)
    if name == "inf":  # ±inf islands in duplicate-heavy noise
        x = rng.choice(np.asarray([0.0, 1.0], F32), size=(b, n)).astype(F32)
        x[rng.random((b, n)) < 0.02] = np.inf
        x[rng.random((b, n)) < 0.02] = -np.inf
        return x
    if name == "fmax":  # dtype-max magnitudes (softmax would overflow;
        #                 the cut itself must still be exact)
        x = rng.standard_normal((b, n)).astype(F32)
        x[rng.random((b, n)) < 0.05] = np.finfo(F32).max
        x[rng.random((b, n)) < 0.05] = np.finfo(F32).min
        return x
    assert name == "equal"
    return np.zeros((b, n), F32)


@pytest.mark.parametrize("fanout", FANOUTS)
@pytest.mark.parametrize("case", ["dups", "inf", "fmax", "equal"])
def test_batched_topk_bitexact_vs_per_request(case, fanout):
    """The batched cut must equal the per-request tournament row by row
    — values AND indices — on tie/inf/dtype-max logits."""
    logits = _case(case)
    k = 16
    bv, bi = batched_topk(jnp.asarray(logits), k, fanout=fanout)
    for i in range(logits.shape[0]):
        rv, ri = merge_topk(jnp.asarray(logits[i]), k, fanout=fanout)
        np.testing.assert_array_equal(np.asarray(bv[i]), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(bi[i]), np.asarray(ri))


@pytest.mark.parametrize("fanout", FANOUTS)
@pytest.mark.parametrize("case", ["dups", "inf", "fmax"])
def test_batched_topk_matches_lax_top_k(case, fanout):
    """External oracle: jax.lax.top_k breaks ties toward the lower
    index, exactly our stability rule."""
    logits = jnp.asarray(_case(case))
    k = 16
    bv, bi = batched_topk(logits, k, fanout=fanout)
    ov, oi = jax.lax.top_k(logits, k)
    np.testing.assert_array_equal(np.asarray(bv), np.asarray(ov))
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(oi))


@pytest.mark.parametrize("fanout", FANOUTS)
def test_equal_logits_resolve_to_lowest_token_ids(fanout):
    vals, idx = batched_topk(jnp.asarray(_case("equal")), 8, fanout=fanout)
    np.testing.assert_array_equal(
        np.asarray(idx), np.tile(np.arange(8, dtype=np.int32), (5, 1))
    )
    assert np.all(np.asarray(vals) == 0.0)


@pytest.mark.parametrize("fanout", FANOUTS)
@pytest.mark.parametrize("case", ["dups", "inf"])
def test_sample_topk_batched_matches_reference(case, fanout):
    """Same per-row keys => identical token draws (probs are built from
    bit-identical cut values, so the categorical sees the same table)."""
    logits = jnp.asarray(_case(case, b=6, n=512))
    key = jax.random.key(3)
    ref = sample_topk(key, logits, k=16, fanout=fanout)
    keys = jax.random.split(key, 6)  # the reference's internal split
    got = sample_topk_batched(keys, logits, k=16, fanout=fanout)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


@pytest.mark.parametrize("fanout", FANOUTS)
@pytest.mark.parametrize("case", ["dups", "inf"])
def test_sample_topp_batched_matches_reference(case, fanout):
    """The value-keyed nucleus cut must reproduce the reference's
    ``cum - probs < p`` prefix mask exactly."""
    logits = jnp.asarray(_case(case, b=6, n=512))
    key = jax.random.key(5)
    ref = sample_topp(key, logits, p=0.7, k=32, fanout=fanout)
    keys = jax.random.split(key, 6)
    got = sample_topp_batched(keys, logits, p=0.7, k=32, fanout=fanout)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


# ---------------------------------------------------------------------------
# scheduler / pool properties
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = smoke_config(ARCHS["qwen3-0.6b"])
    params, _ = init_params(cfg, jax.random.key(0))
    return cfg, params


def test_fifo_admission_order():
    sched = Scheduler(max_batch=2, queue_depth=8)
    for rid in range(6):
        assert sched.submit(Request(rid, np.asarray([1]), 1))
    assert [r.rid for _, r in sched.admit([0, 1])] == [0, 1]
    sched.complete(0)
    assert [r.rid for _, r in sched.admit([0])] == [2]
    sched.check_invariants()


def test_queue_depth_backpressure():
    sched = Scheduler(max_batch=1, queue_depth=2)
    assert sched.submit(Request(0, np.asarray([1]), 1))
    assert sched.submit(Request(1, np.asarray([1]), 1))
    assert not sched.submit(Request(2, np.asarray([1]), 1))  # shed, not drop
    sched.check_invariants()
    assert sched.pending == 2


def test_pool_double_free_and_exhaustion_raise(smoke_model):
    cfg, _ = smoke_model
    pool = KVPool(cfg, capacity=2, max_len=8)
    a, b = pool.alloc(), pool.alloc()
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc()
    pool.free(a)
    with pytest.raises(RuntimeError, match="not in use"):
        pool.free(a)
    pool.free(b)
    pool.check_invariants()


def test_pool_recycle_resets_length_only(smoke_model):
    cfg, _ = smoke_model
    pool = KVPool(cfg, capacity=2, max_len=8)
    slot = pool.alloc()
    pool.set_cache(pool.cache.data, pool.cache.length.at[slot].set(5))
    pool.free(slot)
    again = pool.alloc()  # LIFO: same slot comes back
    assert again == slot
    assert int(pool.cache.length[slot]) == 0  # recycled: masked, not zeroed
    pool.check_invariants()


@given(st.data())
@settings(max_examples=15, deadline=None)
def test_no_slot_leak_random_traces(data):
    """Conservation + FIFO + pool partition under arbitrary interleaved
    submit/admit/complete traces (the continuous-batching state machine
    driven without a model)."""
    cap = data.draw(st.integers(1, 4))
    depth = data.draw(st.integers(1, 5))
    sched = Scheduler(cap, depth)
    free = list(range(cap))
    rid = 0
    for _ in range(data.draw(st.integers(5, 40))):
        op = data.draw(st.sampled_from(["submit", "admit", "complete"]))
        if op == "submit":
            if sched.submit(Request(rid, np.asarray([1, 2]), 1)):
                rid += 1
        elif op == "admit":
            placed = sched.admit(free)
            free = free[len(placed):]
        elif op == "complete" and sched.occupied():
            slot, _ = sched.occupied()[0]
            sched.complete(slot)
            free.append(slot)
        sched.check_invariants()
        assert len(free) + sched.active_slots == cap


# ---------------------------------------------------------------------------
# engine determinism + slot-recycling isolation (smoke model)
# ---------------------------------------------------------------------------


def _engine(cfg, params, **kw):
    kw.setdefault("max_len", 32)
    kw.setdefault("max_batch", 2)
    kw.setdefault("queue_depth", 8)
    kw.setdefault("sampler", "topk")
    kw.setdefault("top_k", 8)
    kw.setdefault("seed", 11)
    return DecodeEngine(cfg, params, **kw)


def _arrivals(cfg, n=4):
    rng = np.random.default_rng(9)
    return [
        (i, Request(i, rng.integers(1, cfg.vocab, 2 + i % 2,
                                    dtype=np.int32), 3 + i % 3))
        for i in range(n)
    ]


def test_streams_invariant_to_pool_size(smoke_model):
    """Token streams depend on (seed, rid), never on slot assignment or
    batch composition: shrinking the pool reorders execution but not
    one request's tokens."""
    cfg, params = smoke_model
    out4 = _engine(cfg, params, max_batch=4).run(arrivals=_arrivals(cfg))
    out1 = _engine(cfg, params, max_batch=1).run(arrivals=_arrivals(cfg))
    assert out4 == out1


def test_streams_identical_across_two_compilations(smoke_model):
    """Fixed seed => byte-identical streams even after the jit caches
    are dropped and every entrypoint recompiles."""
    cfg, params = smoke_model
    first = _engine(cfg, params).run(arrivals=_arrivals(cfg))
    jax.clear_caches()
    second = _engine(cfg, params).run(arrivals=_arrivals(cfg))
    assert first == second


def test_recycled_slot_matches_fresh_pool(smoke_model):
    """A request decoded in a recycled slot sees no trace of the slot's
    previous occupant: same stream as in a brand-new pool."""
    cfg, params = smoke_model
    probe = Request(77, np.asarray([3, 1, 4], np.int32), 5)
    eng = _engine(cfg, params, max_batch=1)
    eng.submit(Request(5, np.asarray([9, 9, 9, 9], np.int32), 6))
    out = eng.run(arrivals=[(1, probe)])  # probe reuses rid-5's slot
    fresh = _engine(cfg, params, max_batch=1).run(
        arrivals=[(0, Request(77, probe.prompt, probe.max_new_tokens))]
    )
    assert out[77] == fresh[77]


def test_engine_rejects_oversized_request(smoke_model):
    cfg, params = smoke_model
    eng = _engine(cfg, params, max_len=8)
    with pytest.raises(ValueError, match="exceeds pool max_len"):
        eng.submit(Request(0, np.arange(1, 7, dtype=np.int32), 4))


# ---------------------------------------------------------------------------
# obs satellites
# ---------------------------------------------------------------------------


def test_attach_hlo_report_logs_failure_type():
    """attach_hlo_report must swallow failures but leave an event with
    the exception type behind — never a silent pass, never a crash."""
    with obs.capture() as records:
        out = obs.attach_hlo_report("bogus_entry", 12345)
    assert out is None
    evs = [r for r in records if r["metric"] == "hlo.report_failed"]
    assert len(evs) == 1
    assert evs[0]["labels"]["entry"] == "bogus_entry"
    assert evs[0]["labels"]["error_type"]  # the type name, not just repr


def test_topk_candidates_counter_is_batch_linear_rounds_constant():
    """The serve.topk_* evidence: merge rounds are a pure function of
    (vocab, fanout) — identical for batch 1 and 8 — while the final-cut
    candidate count scales with batch."""
    rows = {}
    for b in (1, 8):
        with obs.capture() as records:
            jax.block_until_ready(
                batched_topk(jnp.asarray(_case("dups", b=b)), 8, fanout=4)
            )
        rows[b] = {
            r["metric"]: r["value"] for r in records
            if r["metric"].startswith("serve.topk")
        }
    assert rows[1]["serve.topk_merge_rounds"] == \
        rows[8]["serve.topk_merge_rounds"]
    assert rows[8]["serve.topk_candidates"] == \
        8 * rows[1]["serve.topk_candidates"]


# ---------------------------------------------------------------------------
# e2e smoke decode (subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow  # full-stack staggered-arrival decode in a subprocess
def test_serve_smoke_e2e():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "_serve_check.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=1800,
    )
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    assert "ok: active_slots <= capacity" in proc.stdout
    assert "ok: byte-identical streams on rerun" in proc.stdout
