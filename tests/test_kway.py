"""Tests for the multi-way co-rank and k-way merge subsystem."""

import numpy as np
import pytest
import jax.numpy as jnp

from _prop import given, settings, st
from repro.core import (
    co_rank_kway,
    co_rank_kway_batch,
    merge_by_ranking,
    merge_kway,
    merge_kway_ranked,
    merge_sort,
    merge_argsort,
    merge_topk,
    sort_key_val,
)
from repro.kernels.merge import merge_kway_pallas


def oracle_cuts(runs, i):
    """Reference cut vector: stably merge with (value, run, pos) keys and
    count per-run contributions to the first ``i`` outputs."""
    k, w = runs.shape
    tagged = sorted((runs[r, t], r, t) for r in range(k) for t in range(w))
    j = np.zeros(k, np.int64)
    for _, r, _ in tagged[:i]:
        j[r] += 1
    return j


def pairwise_lemma_holds(runs, j):
    """The k-way cut must satisfy Lemma 1 for every ordered run pair:
    for q < r, no kept element of r may precede a dropped one of q and
    vice versa (ties resolve toward the lower run index)."""
    k, w = runs.shape
    for q in range(k):
        for r in range(q + 1, k):
            # kept-prefix of q ends before dropped-suffix of r starts
            if j[q] > 0 and j[r] < w and not runs[q][j[q] - 1] <= runs[r][j[r]]:
                return False
            if j[r] > 0 and j[q] < w and not runs[r][j[r] - 1] < runs[q][j[q]]:
                return False
    return True


def rand_runs(rng, k, w, lo=0, hi=10, dtype=np.int32):
    return np.sort(rng.integers(lo, hi, (k, w)), axis=1).astype(dtype)


@pytest.mark.parametrize("k,w", [(2, 8), (3, 5), (4, 16), (8, 7), (16, 3)])
def test_co_rank_kway_matches_oracle(k, w):
    rng = np.random.default_rng(k * 100 + w)
    runs = rand_runs(rng, k, w)
    ranks = jnp.arange(k * w + 1, dtype=jnp.int32)
    cuts = np.asarray(co_rank_kway_batch(ranks, jnp.asarray(runs)))
    for i in range(k * w + 1):
        j = cuts[i]
        np.testing.assert_array_equal(j, oracle_cuts(runs, i)), (k, w, i)
        assert j.sum() == i
        assert pairwise_lemma_holds(runs, j), (k, w, i, j)


def test_co_rank_kway_cut_sum_invariant():
    """sum(j_r) == i for every rank, heavy-duplicate input."""
    rng = np.random.default_rng(0)
    runs = rand_runs(rng, 8, 32, lo=0, hi=3)  # massive duplication
    ranks = jnp.arange(8 * 32 + 1, dtype=jnp.int32)
    cuts = np.asarray(co_rank_kway_batch(ranks, jnp.asarray(runs)))
    np.testing.assert_array_equal(cuts.sum(axis=1), np.asarray(ranks))


def test_co_rank_kway_all_equal_stability():
    """All-equal keys: cuts must drain runs strictly in run order."""
    runs = jnp.zeros((4, 8), jnp.int32)
    cuts = np.asarray(
        co_rank_kway_batch(jnp.arange(33, dtype=jnp.int32), runs)
    )
    for i in range(33):
        want = np.clip([i, i - 8, i - 16, i - 24], 0, 8)
        np.testing.assert_array_equal(cuts[i], want)


def test_co_rank_kway_ragged_lengths():
    rng = np.random.default_rng(5)
    k, w = 4, 10
    lengths = np.array([10, 3, 7, 1], np.int32)
    runs = np.full((k, w), np.iinfo(np.int32).max, np.int32)
    for r in range(k):
        runs[r, : lengths[r]] = np.sort(rng.integers(0, 6, lengths[r]))
    total = int(lengths.sum())
    cuts = np.asarray(
        co_rank_kway_batch(
            jnp.arange(total + 1, dtype=jnp.int32),
            jnp.asarray(runs),
            jnp.asarray(lengths),
        )
    )
    for i in range(total + 1):
        assert cuts[i].sum() == i
        assert (cuts[i] <= lengths).all()


@pytest.mark.parametrize("k,w", [(2, 64), (4, 33), (8, 17), (16, 9)])
@pytest.mark.parametrize("p", [1, 3, 8, 16])
def test_merge_kway_values(k, w, p):
    rng = np.random.default_rng(k * w + p)
    runs = rand_runs(rng, k, w, hi=50)
    got = np.asarray(merge_kway(jnp.asarray(runs), p=p))
    np.testing.assert_array_equal(
        got, np.sort(runs.reshape(-1), kind="stable")
    )


def test_merge_kway_stability_duplicates():
    """Duplicate-heavy keys with an index payload: payload order must be
    the global stable order (run-major, then position)."""
    rng = np.random.default_rng(9)
    k, w = 6, 40
    runs = rand_runs(rng, k, w, hi=4)  # only 4 distinct keys
    ids = np.arange(k * w, dtype=np.int32).reshape(k, w)
    keys, got_ids = merge_kway_ranked(jnp.asarray(runs), jnp.asarray(ids))
    want_order = np.argsort(runs.reshape(-1), kind="stable")
    np.testing.assert_array_equal(np.asarray(got_ids), want_order)
    np.testing.assert_array_equal(
        np.asarray(keys), np.sort(runs.reshape(-1), kind="stable")
    )


def test_merge_kway_agrees_with_pairwise_folds():
    """k-way merge == fold of the paper's pairwise merge_by_ranking."""
    rng = np.random.default_rng(11)
    k, w = 8, 25
    runs = rand_runs(rng, k, w, hi=12)
    folded = jnp.asarray(runs[0])
    for r in range(1, k):
        folded = merge_by_ranking(folded, jnp.asarray(runs[r]))
    got = np.asarray(merge_kway(jnp.asarray(runs), p=5))
    np.testing.assert_array_equal(got, np.asarray(folded))


def test_merge_kway_ranked_ragged():
    rng = np.random.default_rng(13)
    k, w = 3, 8
    lengths = np.array([8, 2, 5], np.int32)
    runs = np.full((k, w), np.iinfo(np.int32).max, np.int32)
    parts = []
    for r in range(k):
        runs[r, : lengths[r]] = np.sort(rng.integers(0, 5, lengths[r]))
        parts.append(runs[r, : lengths[r]])
    total = int(lengths.sum())
    got = np.asarray(
        merge_kway_ranked(
            jnp.asarray(runs), lengths=jnp.asarray(lengths), out_len=total
        )
    )
    np.testing.assert_array_equal(
        got, np.sort(np.concatenate(parts), kind="stable")
    )


@pytest.mark.parametrize("fanout", [2, 4, 8, 16])
@pytest.mark.parametrize("n", [1, 2, 37, 64, 257, 1000])
def test_sort_fanout_sweep(fanout, n):
    rng = np.random.default_rng(fanout * 10000 + n)
    x = rng.integers(-100, 100, n).astype(np.int32)
    got = np.asarray(merge_sort(jnp.asarray(x), fanout))
    np.testing.assert_array_equal(got, np.sort(x, kind="stable"))


@pytest.mark.parametrize("fanout", [2, 4, 8, 16])
def test_argsort_fanout_stable(fanout):
    rng = np.random.default_rng(fanout)
    x = rng.integers(0, 4, 333).astype(np.int32)  # heavy duplicates
    got = np.asarray(merge_argsort(jnp.asarray(x), fanout))
    np.testing.assert_array_equal(got, np.argsort(x, kind="stable"))


def test_sort_fanout_agreement_across_fanouts():
    """Every fanout must produce the identical (stable) permutation."""
    rng = np.random.default_rng(21)
    keys = rng.integers(0, 8, 500).astype(np.int32)
    vals = np.arange(500, dtype=np.int32)
    outs = []
    for fanout in (2, 4, 8, 16):
        k, v = sort_key_val(jnp.asarray(keys), jnp.asarray(vals), fanout)
        outs.append((np.asarray(k), np.asarray(v)))
    for k, v in outs[1:]:
        np.testing.assert_array_equal(k, outs[0][0])
        np.testing.assert_array_equal(v, outs[0][1])


@pytest.mark.parametrize("fanout", [2, 4, 16])
def test_topk_tournament_fanout(fanout):
    rng = np.random.default_rng(fanout + 40)
    x = rng.standard_normal(3000).astype(np.float32)
    vals, idx = merge_topk(jnp.asarray(x), 17, block=128, fanout=fanout)
    order = np.argsort(-x, kind="stable")[:17]
    np.testing.assert_allclose(np.asarray(vals), x[order], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), order)


# ---------------------------------------------------------------------------
# Pallas kernel: interpret-mode sweep of shapes x dtypes x fanouts
# ---------------------------------------------------------------------------


@pytest.mark.slow  # 24-cell interpret-mode sweep: minutes of tracing
@pytest.mark.parametrize("dtype", [np.int32, np.float32, "bfloat16"])
@pytest.mark.parametrize("k,w", [(2, 1000), (4, 513), (8, 300), (16, 65)])
@pytest.mark.parametrize("tile", [128, 256])
def test_merge_kway_pallas_sweep(dtype, k, w, tile):
    rng = np.random.default_rng(abs(hash((str(dtype), k, w, tile))) % 2**32)
    if dtype == "bfloat16":
        # small integer-valued floats: exact in bf16, avoids rounding
        # reorders vs the float oracle
        base = np.sort(rng.integers(-250, 250, (k, w)), axis=1).astype(
            np.float32
        )
        runs = jnp.asarray(base, jnp.bfloat16)
        got = np.asarray(merge_kway_pallas(runs, tile=tile)).astype(np.float32)
        want = np.sort(base.reshape(-1), kind="stable")
    else:
        base = np.sort(rng.integers(-1000, 1000, (k, w)), axis=1).astype(dtype)
        got = np.asarray(merge_kway_pallas(jnp.asarray(base), tile=tile))
        want = np.sort(base.reshape(-1), kind="stable")
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_merge_kway_pallas_stability_tagged():
    """Ties across runs resolve by run index: parity-style tag check."""
    rng = np.random.default_rng(17)
    k, w = 4, 700
    base = np.sort(rng.integers(0, 6, (k, w)), axis=1)
    runs = (base * 8 + np.arange(k)[:, None]).astype(np.int32)
    got = np.asarray(merge_kway_pallas(jnp.asarray(runs), tile=128))
    vals, origin = got // 8, got % 8
    np.testing.assert_array_equal(np.sort(vals, kind="stable"), vals)
    for v in np.unique(vals):
        seg = origin[vals == v]
        assert not np.any(np.diff(seg) < 0), f"instability at key {v}"


def test_merge_kway_pallas_adversarial_skew():
    """Run r entirely below run r+1 — worst case for equidistant
    partitions, exactly balanced for the multi-way co-rank."""
    k, w = 4, 512
    runs = jnp.arange(k * w, dtype=jnp.int32).reshape(k, w)
    got = np.asarray(merge_kway_pallas(runs, tile=256))
    np.testing.assert_array_equal(got, np.arange(k * w, dtype=np.int32))


def test_merge_kway_pallas_matches_xla_path():
    rng = np.random.default_rng(23)
    runs = np.sort(rng.standard_normal((8, 400)), axis=1).astype(np.float32)
    got = merge_kway_pallas(jnp.asarray(runs), tile=128)
    want = merge_kway_ranked(jnp.asarray(runs))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Proposition 1 at runtime: the recorded iteration counters
# ---------------------------------------------------------------------------


def test_prop1_runtime_iteration_counters():
    """The obs-layer ``corank.iterations`` records must respect Prop 1's
    ``ceil(log2 min(m, n)) + 1`` bound on live searches — the counter the
    paper's complexity claim is audited with in production runs."""
    from repro import obs
    from repro.core.corank import co_rank, prop1_bound

    rng = np.random.default_rng(31)
    cases = [(1, 1), (2, 7), (8, 8), (33, 7), (128, 128), (5, 1000)]
    with obs.capture() as recs:
        for m, n in cases:
            a = jnp.asarray(np.sort(rng.integers(-50, 50, m)), np.int32)
            b = jnp.asarray(np.sort(rng.integers(-50, 50, n)), np.int32)
            for i in (0, 1, (m + n) // 2, m + n - 1, m + n):
                co_rank(i, a, b)
        obs.flush()
        its = [r for r in recs if r["metric"] == "corank.iterations"]
        assert len(its) == 5 * len(cases)
        for r in its:
            m, n = r["labels"]["m"], r["labels"]["n"]
            assert r["labels"]["bound"] == prop1_bound(m, n)
            assert r["max"] <= r["labels"]["bound"], (
                f"Prop 1 violated for (m={m}, n={n}): "
                f"{r['max']} > {r['labels']['bound']}"
            )


# ---------------------------------------------------------------------------
# properties (hypothesis when installed, seeded fallback offline)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    st.integers(2, 8),
    st.integers(1, 40),
    st.integers(0, 2**31 - 1),
    st.data(),
)
def test_co_rank_kway_property(k, w, seed, data):
    rng = np.random.default_rng(seed)
    runs = rand_runs(rng, k, w, lo=-9, hi=9)
    i = data.draw(st.integers(0, k * w))
    j = np.asarray(co_rank_kway(i, jnp.asarray(runs)))
    np.testing.assert_array_equal(j, oracle_cuts(runs, i))
    assert pairwise_lemma_holds(runs, j)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(2, 8),
    st.integers(1, 30),
    st.integers(1, 12),
    st.integers(0, 2**31 - 1),
)
def test_merge_kway_property(k, w, p, seed):
    rng = np.random.default_rng(seed)
    runs = rand_runs(rng, k, w, lo=-20, hi=20)
    got = np.asarray(merge_kway(jnp.asarray(runs), p=p))
    np.testing.assert_array_equal(
        got, np.sort(runs.reshape(-1), kind="stable")
    )


@pytest.mark.slow  # every example re-traces the interpret-mode kernel
@settings(max_examples=20, deadline=None)
@given(
    st.sampled_from([2, 4, 8]),
    st.integers(1, 60),
    st.integers(0, 2**31 - 1),
)
def test_merge_kway_pallas_property(k, w, seed):
    rng = np.random.default_rng(seed)
    runs = rand_runs(rng, k, w, lo=-20, hi=20)
    got = np.asarray(merge_kway_pallas(jnp.asarray(runs), tile=128))
    np.testing.assert_array_equal(
        got, np.sort(runs.reshape(-1), kind="stable")
    )
