"""Distributed lane of the engine equivalence sweep (8 fake devices).

Invoked by tests/test_engine.py; exits nonzero on any failure.  The
collective instantiations of the co-rank engine —
``distributed_co_rank_kway`` (k = p = 8, ragged ``length`` sideband) and
``distributed_co_rank`` (pairwise Algorithm 1 over remote reads) — must
return exactly the cuts of the device tier and of the brute-force
oracle on the shared cases in ``_engine_cases``.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.kway import co_rank_kway_batch
from repro.distributed.splitters import (
    distributed_co_rank,
    distributed_co_rank_kway,
)

from _engine_cases import (
    kway_cases,
    oracle_cuts,
    oracle_pairwise,
    pairwise_cases,
    rank_sweep,
)


def check_kway(mesh, p):
    for name, runs, lengths in kway_cases(p):
        w = runs.shape[1]
        total = int(lengths.sum())
        sweep = np.asarray(rank_sweep(total), np.int64)
        # Device r asks the sweep shifted by r (distinct per-device
        # batches, exercising the batched lock-step search).
        ranks = np.clip(
            sweep[None, :] + np.arange(p)[:, None], 0, total
        ).astype(np.int32)

        def spl(i_shard, run_shard, len_shard):
            return distributed_co_rank_kway(
                i_shard[0], run_shard[0], "x", length=len_shard[0, 0]
            )[None]

        fn = shard_map(
            spl,
            mesh=mesh,
            in_specs=(P("x"), P("x"), P("x")),
            out_specs=P("x"),
        )
        cuts = np.asarray(
            jax.jit(fn)(
                jnp.asarray(ranks),
                jnp.asarray(runs),
                jnp.asarray(lengths)[:, None],
            )
        )  # (p, B, p)

        for r in range(p):
            device = np.asarray(
                co_rank_kway_batch(
                    jnp.asarray(ranks[r]),
                    jnp.asarray(runs),
                    jnp.asarray(lengths),
                )
            )
            np.testing.assert_array_equal(
                cuts[r], device, err_msg=f"{name} dev{r}: vs device tier"
            )
            for bi, i in enumerate(ranks[r]):
                np.testing.assert_array_equal(
                    cuts[r, bi],
                    oracle_cuts(runs, lengths, int(i)),
                    err_msg=f"{name} dev{r} i={i}: vs oracle",
                )
        print(f"kway[{name}]: OK")


def check_pairwise(mesh, p):
    for name, a, b in pairwise_cases():
        # Pad both sides up to a multiple of p with their max (padding
        # past the searched ranks never changes the co-ranks below m+n).
        def pad(x):
            t = -(-max(len(x), 1) // p) * p
            fill = x[-1] if len(x) else np.zeros((), x.dtype)
            return np.concatenate([x, np.full(t - len(x), fill, x.dtype)])

        ap, bp = pad(a), pad(b)
        m, n = len(a), len(b)

        def cr(a_, b_):
            r = jax.lax.axis_index("x")
            i = (r * 41) % (m + n + 1)
            j, k = distributed_co_rank(i, a_, b_, "x")
            return jnp.stack([j, k])[None]

        fn = shard_map(
            cr, mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x")
        )
        jk = np.asarray(jax.jit(fn)(jnp.asarray(ap), jnp.asarray(bp)))
        for r in range(p):
            i = (r * 41) % (m + n + 1)
            want = oracle_pairwise(ap, bp, i)
            assert tuple(jk[r]) == want, (name, r, i, jk[r], want)
        print(f"pairwise[{name}]: OK")


def main():
    devs = jax.devices()
    assert len(devs) == 8, devs
    mesh = Mesh(np.array(devs), ("x",))
    check_kway(mesh, 8)
    check_pairwise(mesh, 8)
    print("ALL OK")


if __name__ == "__main__":
    main()
