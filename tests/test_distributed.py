"""Multi-device merge/sort tests (subprocess: 8 fake host devices).

The main pytest process must keep a single device (smoke tests and
benchmarks expect it), so the 8-device run happens in a child process.
"""

import os
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess run on 8 fake devices

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_distributed_ops_eight_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "_distributed_check.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout
