"""Tests for merge sort / argsort / top-k built on the paper's merge."""

import numpy as np
import pytest
import jax.numpy as jnp
from _prop import given, settings, st

from repro.core import merge_argsort, merge_sort, merge_topk, sort_key_val


@pytest.mark.parametrize("n", [1, 2, 3, 17, 64, 100, 1000])
def test_merge_sort_values(n):
    rng = np.random.default_rng(n)
    x = rng.integers(-100, 100, n).astype(np.int32)
    got = np.asarray(merge_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.sort(x, kind="stable"))


@pytest.mark.parametrize("n", [5, 32, 77, 512])
def test_merge_argsort_stable(n):
    rng = np.random.default_rng(n + 1)
    x = rng.integers(0, 5, n).astype(np.int32)  # heavy duplicates
    got = np.asarray(merge_argsort(jnp.asarray(x)))
    want = np.argsort(x, kind="stable")
    np.testing.assert_array_equal(got, want)


def test_sort_key_val_carries_payload():
    keys = jnp.asarray([3, 1, 2, 1, 3, 0], jnp.int32)
    vals = jnp.asarray([10, 11, 12, 13, 14, 15], jnp.int32)
    k, v = sort_key_val(keys, vals)
    np.testing.assert_array_equal(np.asarray(k), [0, 1, 1, 2, 3, 3])
    np.testing.assert_array_equal(np.asarray(v), [15, 11, 13, 12, 10, 14])


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(-3, 3), min_size=1, max_size=130))
def test_merge_argsort_property(xs):
    x = np.asarray(xs, np.int32)
    got = np.asarray(merge_argsort(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.argsort(x, kind="stable"))


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.floats(-100, 100, allow_nan=False, allow_subnormal=False, width=32),
        min_size=1,
        max_size=200,
    )
)
def test_merge_sort_floats_property(xs):
    x = np.asarray(xs, np.float32)
    got = np.asarray(merge_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.sort(x, kind="stable"))


@pytest.mark.parametrize("n,k", [(100, 5), (1000, 32), (64, 64), (513, 7)])
def test_merge_topk(n, k):
    rng = np.random.default_rng(n * k)
    x = rng.standard_normal(n).astype(np.float32)
    vals, idx = merge_topk(jnp.asarray(x), k)
    order = np.argsort(-x, kind="stable")[:k]
    np.testing.assert_allclose(np.asarray(vals), x[order], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), order)


def test_merge_topk_ties_prefer_low_index():
    x = jnp.asarray([1.0, 2.0, 2.0, 2.0, 0.5], jnp.float32)
    vals, idx = merge_topk(x, 3)
    np.testing.assert_array_equal(np.asarray(idx), [1, 2, 3])
