"""Per-architecture smoke tests: reduced same-family config, one
forward/train/decode step on CPU, asserting shapes and no NaNs."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, input_specs, smoke_config
from repro.models.transformer import (
    decode_step,
    hidden_states,
    init_cache,
    init_params,
    train_loss,
)

pytestmark = pytest.mark.slow  # full arch sweep: minutes of compile time

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def make_batch(cfg, rng):
    b, s = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_tokens, cfg.d_model)),
            jnp.bfloat16,
        )
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_loss(arch):
    cfg = smoke_config(ARCHS[arch])
    rng = np.random.default_rng(0)
    params, specs = init_params(cfg, jax.random.key(0))
    # spec tree must match param tree structure
    assert jax.tree.structure(
        jax.tree.map(lambda _: 0, params)
    ) == jax.tree.structure(jax.tree.map(lambda _: 0, specs))

    batch = make_batch(cfg, rng)
    h = hidden_states(cfg, params, batch["tokens"], batch.get("frontend_embeds"))
    assert h.shape == (2, 32, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(h.astype(jnp.float32))))

    loss = jax.jit(lambda p, b: train_loss(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), float(loss)
    # random init, uniform labels: loss should be near log(vocab)
    assert 0.2 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_grad_step(arch):
    cfg = smoke_config(ARCHS[arch])
    rng = np.random.default_rng(1)
    params, _ = init_params(cfg, jax.random.key(1))
    batch = make_batch(cfg, rng)

    loss, grads = jax.jit(
        lambda p, b: jax.value_and_grad(lambda pp: train_loss(cfg, pp, b))(p)
    )(params, batch)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in flat)
    # at least some gradient signal reaches the embedding table
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in flat)
    assert gnorm > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_step(arch):
    cfg = smoke_config(ARCHS[arch])
    params, _ = init_params(cfg, jax.random.key(2))
    b, max_len = 2, 16
    cache = init_cache(cfg, b, max_len)
    tok = jnp.zeros((b, 1), jnp.int32)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    logits, cache = step(params, cache, tok)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache.length) == 1
    logits2, cache = step(params, cache, tok)
    assert int(cache.length) == 2
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_decode_matches_prefill_dense():
    """Teacher-forced decode must reproduce prefill hidden states."""
    cfg = smoke_config(ARCHS["granite-3-2b"])
    params, _ = init_params(cfg, jax.random.key(3))
    rng = np.random.default_rng(3)
    b, s = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    h = hidden_states(cfg, params, toks)
    from repro.models.layers import embed
    from repro.models.transformer import _unembed_table

    logits_prefill = jnp.einsum(
        "bsd,vd->bsv", h, _unembed_table(cfg, params).astype(h.dtype)
    ).astype(jnp.float32)

    cache = init_cache(cfg, b, s)
    outs = []
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    for t in range(s):
        lg, cache = step(params, cache, toks[:, t : t + 1])
        outs.append(lg)
    logits_decode = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_decode),
        np.asarray(logits_prefill),
        rtol=0.1,
        atol=0.15,
    )


def test_input_specs_cover_all_cells():
    from repro.configs.base import SHAPES
    from repro.configs.registry import cell_runnable

    n_cells = n_run = 0
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            n_cells += 1
            ok, why = cell_runnable(cfg, shape)
            if not ok:
                assert shape.name == "long_500k" and not cfg.ssm
                continue
            n_run += 1
            spec = input_specs(cfg, shape)
            assert "tokens" in spec
    assert n_cells == 40
    assert n_run == 40 - 8  # 8 full-attention archs skip long_500k
