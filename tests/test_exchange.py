"""Sharded exchange subsystem tests (subprocess: 8 fake host devices).

The main pytest process must keep a single device (smoke tests and
benchmarks expect it), so the 8-device runs happen in child processes —
mirroring tests/test_distributed.py.  ``scripts/verify.sh --distributed``
runs this file (and the distributed suite) explicitly.
"""

import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(script: str, *args: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / script), *args],
        capture_output=True,
        text=True,
        env=env,
        timeout=1800,
    )
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    return proc.stdout


@pytest.mark.slow  # subprocess run on 8 fake devices
def test_exchange_eight_devices():
    out = _run("_exchange_check.py")
    assert "ALL OK" in out
    assert "HLO: exchange all-gathers" in out


@pytest.mark.slow  # widest shape sweep: the long lane of the exchange suite
def test_exchange_eight_devices_sweep():
    out = _run("_exchange_check.py", "--sweep")
    assert "ALL OK" in out
