"""Single-device semantic tests for the dropless MoE dispatch path:
bit-exactness against the dense reference, zero drops, grouped-GEMM
correctness, determinism, and the config/CLI plumbing."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS
from repro.models.moe import (
    grouped_gemm,
    init_moe,
    moe_apply,
    moe_dense_reference,
    moe_dispatch_dropless,
)

D, FF, E, K = 16, 32, 8, 2


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    params, _ = init_moe(jax.random.key(0), D, FF, E)
    params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
    x = jnp.asarray(rng.standard_normal((2, 16, D)), jnp.float32)
    return params, x


def test_dropless_bitexact_vs_dense_reference(setup):
    params, x = setup
    ref = moe_dense_reference(params, x, n_experts=E, top_k=K)
    got = moe_apply(params, x, n_experts=E, top_k=K, capacity_factor=1.25,
                    dispatch="dropless")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_dropless_bitexact_one_hot_routing(setup):
    """Adversarial router: every token picks the same expert."""
    params, x = setup
    p2 = dict(params)
    p2["router"] = jnp.zeros((D, E)).at[:, 3].set(10.0)
    ref = moe_dense_reference(p2, x, n_experts=E, top_k=K)
    got = moe_apply(p2, x, n_experts=E, top_k=K, capacity_factor=1.25,
                    dispatch="dropless")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_dropless_zero_drops(setup):
    """Every assignment is dispatched: group sizes sum to T*k always."""
    _, x = setup
    rng = np.random.default_rng(1)
    for experts_np in (
        rng.integers(0, E, (64, K)),
        np.full((64, K), 0),  # one-hot skew
    ):
        _, sorted_idx, gs = moe_dispatch_dropless(
            jnp.asarray(experts_np, jnp.int32), E
        )
        assert int(gs.sum()) == 64 * K
        # sorted_idx is a permutation — unique scatter targets
        assert len(np.unique(np.asarray(sorted_idx))) == 64 * K


def test_capacity_with_headroom_matches_dropless(setup):
    """A capacity factor too large to drop anything must agree with the
    dropless path numerically (different slot layout, same math)."""
    params, x = setup
    drop = moe_apply(params, x, n_experts=E, top_k=K, capacity_factor=1.0,
                     dispatch="dropless")
    cap = moe_apply(params, x, n_experts=E, top_k=K, capacity_factor=100.0,
                    dispatch="capacity")
    np.testing.assert_allclose(
        np.asarray(cap), np.asarray(drop), rtol=1e-5, atol=1e-5
    )


def test_dispatch_validation_error(setup):
    params, x = setup
    with pytest.raises(ValueError, match="dispatch"):
        moe_apply(params, x, n_experts=E, top_k=K, capacity_factor=1.0,
                  dispatch="bogus")


def test_grouped_gemm_matches_per_group_loop():
    rng = np.random.default_rng(2)
    gs = jnp.asarray([3, 0, 5, 4, 0, 2, 1, 1], jnp.int32)
    m = int(gs.sum())
    x = jnp.asarray(rng.standard_normal((m + 4, D)), jnp.float32)  # +padding
    w = jnp.asarray(rng.standard_normal((E, D, FF)), jnp.float32)
    got = np.asarray(grouped_gemm(x, w, gs))
    off = 0
    for e in range(E):
        n_e = int(gs[e])
        want = np.asarray(x[off : off + n_e] @ w[e])
        np.testing.assert_array_equal(got[off : off + n_e], want)
        off += n_e
    # rows beyond sum(group_sizes) are inert zeros
    np.testing.assert_array_equal(got[m:], 0.0)


def test_dropless_determinism_two_compilations(setup):
    params, x = setup
    f1 = jax.jit(lambda p, xx: moe_apply(
        p, xx, n_experts=E, top_k=K, capacity_factor=1.0,
        dispatch="dropless"))
    f2 = jax.jit(lambda p, xx: moe_apply(
        p, xx, n_experts=E, top_k=K, capacity_factor=1.0,
        dispatch="dropless") * 1.0)
    np.testing.assert_array_equal(
        np.asarray(f1(params, x)), np.asarray(f2(params, x))
    )


def test_config_field_defaults_and_threading():
    cfg = ModelConfig(
        name="t", family="dense", n_layers=1, d_model=8, n_heads=2,
        n_kv_heads=2, d_ff=16, vocab=32,
    )
    assert cfg.moe_dispatch == "capacity"
    assert ARCHS["dbrx-132b"].moe_dispatch == "dropless"
    assert ARCHS["deepseek-v3-671b"].moe_dispatch == "dropless"
    assert dataclasses.replace(cfg, moe_dispatch="dropless").moe_dispatch \
        == "dropless"


def test_moe_layer_forward_with_dropless_config():
    """A reduced MoE transformer runs end-to-end with dropless dispatch
    and produces finite outputs identical across dispatch only in shape
    (capacity drops tokens, dropless does not)."""
    from repro.configs.registry import smoke_config
    from repro.models.transformer import hidden_states, init_params

    cfg = smoke_config(ARCHS["dbrx-132b"])
    assert cfg.moe_dispatch == "dropless"  # threaded through smoke_config
    params, _ = init_params(cfg, jax.random.key(0))
    toks = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab, (2, 8)), jnp.int32
    )
    h = hidden_states(cfg, params, toks)
    assert h.shape == (2, 8, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))
