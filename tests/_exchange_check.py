"""Exchange-subsystem checks, run in a subprocess with 8 fake host devices.

Invoked by tests/test_exchange.py; exits nonzero on any failure.  Covers
the acceptance criteria of the sharded exchange subsystem:

* distributed k-way splitters == single-device ``co_rank_kway_batch``;
* ``sharded_sort(strategy='exchange')`` bit-exact with a global stable
  sort, including duplicate tie-breaking by device order (verified on the
  full argsort *permutation*, carried through the exchange as a payload);
* duplicate-heavy inputs and real dtype-max values coexisting with the
  sentinel padding;
* non-power-of-two / uneven-remainder sizes via the host wrapper;
* HLO inspection: the exchange path contains **no** full-N all-gather of
  values — only O(p^2) int32 metadata collectives and the balanced
  all-to-all — while the allgather strategy (positive control) does.
"""

import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.kway import co_rank_kway_batch, merge_kway_ranked
from repro.launch.hlo_stats import collective_op_sizes
from repro.core.mergesort import sort_key_val
from repro.distributed import (
    distributed_co_rank_kway,
    exchange_block,
    sharded_sort,
    sharded_sort_host,
)

SWEEP = "--sweep" in sys.argv[1:]


def check_splitters(mesh, p, rng):
    """Distributed k-way co-rank == the single-device oracle."""
    for w, lo_v, hi_v in [(64, 0, 50), (128, -3, 3), (32, 0, 2)]:
        x = rng.integers(lo_v, hi_v + 1, p * w).astype(np.int32)
        runs = np.sort(x.reshape(p, w), axis=1)

        def spl(run_shard):
            r = jax.lax.axis_index("x")
            i = jnp.stack([r * w, (r + 1) * w]).astype(jnp.int32)
            return distributed_co_rank_kway(i, run_shard[0], "x")[None]

        fn = shard_map(spl, mesh=mesh, in_specs=(P("x"),), out_specs=P("x"))
        cuts = np.asarray(jax.jit(fn)(jnp.asarray(runs)))
        want = np.asarray(
            co_rank_kway_batch(jnp.arange(p + 1) * w, jnp.asarray(runs))
        )
        for d in range(p):
            np.testing.assert_array_equal(cuts[d, 0], want[d])
            np.testing.assert_array_equal(cuts[d, 1], want[d + 1])
        assert cuts[:, 1].sum(axis=1).tolist() == [
            (d + 1) * w for d in range(p)
        ], "cut vectors must sum to the exact block bound (perfect balance)"

    # ragged runs: per-device real lengths, rows padded with dtype max,
    # the documented `length` sideband of distributed_co_rank_kway
    w = 48
    lens = rng.integers(1, w + 1, p).astype(np.int32)
    runs = np.full((p, w), np.iinfo(np.int32).max, np.int32)
    for d in range(p):
        runs[d, : lens[d]] = np.sort(rng.integers(0, 20, lens[d]))
    total = int(lens.sum())
    step = total // p

    def spl_ragged(run_shard, len_shard):
        r = jax.lax.axis_index("x")
        i = jnp.stack(
            [r * step, jnp.minimum((r + 1) * step, total)]
        ).astype(jnp.int32)
        return distributed_co_rank_kway(
            i, run_shard[0], "x", length=len_shard[0]
        )[None]

    fn = shard_map(
        spl_ragged, mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x")
    )
    cuts = np.asarray(jax.jit(fn)(jnp.asarray(runs), jnp.asarray(lens)))
    bounds = np.array(
        [min(d * step, total) for d in range(p)]
        + [min(p * step, total)]
    )
    want = np.asarray(
        co_rank_kway_batch(
            jnp.asarray(bounds), jnp.asarray(runs), jnp.asarray(lens)
        )
    )
    for d in range(p):
        np.testing.assert_array_equal(cuts[d, 0], want[d])
        np.testing.assert_array_equal(cuts[d, 1], want[d + 1])
        assert cuts[d, 1].sum() == min((d + 1) * step, total)
    print("splitters vs co_rank_kway_batch (uniform + ragged): OK")


def _argsort_exchange(mesh, p, x):
    """Full stable argsort through the exchange: the index payload rides
    a second exchange_block, so the permutation itself crosses the wire
    — duplicates that lose their tie-break would be visible here."""
    w = len(x) // p

    def body(x_shard):
        x_shard = x_shard.reshape(-1)
        r = jax.lax.axis_index("x")
        gidx = r * w + jnp.arange(w, dtype=jnp.int32)
        keys, idx = sort_key_val(x_shard, gidx)
        bounds = jnp.stack([r * w, (r + 1) * w]).astype(jnp.int32)
        cuts = distributed_co_rank_kway(bounds, keys, "x")
        seg_k, lengths = exchange_block(keys, cuts, "x")
        seg_i, _ = exchange_block(idx, cuts, "x")
        out_k, out_i = merge_kway_ranked(
            seg_k, vals=seg_i, lengths=lengths, out_len=w
        )
        return jnp.stack([out_k, out_i])[None]

    fn = shard_map(body, mesh=mesh, in_specs=(P("x"),), out_specs=P("x"))
    out = np.asarray(jax.jit(fn)(jnp.asarray(x)))  # (p, 2, w)
    return out[:, 0].reshape(-1), out[:, 1].reshape(-1)


def check_stability(mesh, p, rng):
    """Bit-exact vs numpy's stable sort INCLUDING the permutation."""
    n = p * 256
    for name, x in [
        ("duplicate-heavy int", rng.integers(-4, 4, n).astype(np.int32)),
        ("few distinct", rng.integers(0, 2, n).astype(np.int32)),
        (
            "dtype-max collisions",
            np.where(
                rng.random(n) < 0.3,
                np.iinfo(np.int32).max,
                rng.integers(0, 10, n),
            ).astype(np.int32),
        ),
    ]:
        keys, perm = _argsort_exchange(mesh, p, x)
        np.testing.assert_array_equal(keys, np.sort(x, kind="stable"))
        np.testing.assert_array_equal(
            perm, np.argsort(x, kind="stable").astype(np.int32),
            err_msg=name,
        )
        print(f"exchange stability [{name}]: OK")


def check_sort_strategies(mesh, p, rng):
    """allgather and exchange agree with numpy and each other."""
    sizes = [(p * 64,), (p * 512,)] + ([(p * 2048,)] if SWEEP else [])
    for (n,) in sizes:
        for dtype, gen in [
            (np.int32, lambda: rng.integers(-50, 50, n)),
            (np.float32, lambda: rng.normal(size=n)),
        ]:
            x = gen().astype(dtype)
            want = np.sort(x, kind="stable")
            for strategy in ("allgather", "exchange"):
                fn = shard_map(
                    lambda s, st=strategy: sharded_sort(s, "x", strategy=st),
                    mesh=mesh,
                    in_specs=(P("x"),),
                    out_specs=P("x"),
                )
                got = np.asarray(jax.jit(fn)(jnp.asarray(x)))
                np.testing.assert_array_equal(got, want, err_msg=strategy)
        print(f"sharded_sort strategies agree (n={n}): OK")


def check_uneven(mesh, p, rng):
    """Non-power-of-two / uneven-remainder sizes via sentinel padding."""
    sizes = [7, p - 1, p + 1, 777, 1000, 4097]
    for n in sizes:
        x = rng.integers(-9, 9, n).astype(np.int32)
        got = np.asarray(
            sharded_sort_host(jnp.asarray(x), strategy="exchange", mesh=mesh)
        )
        np.testing.assert_array_equal(got, np.sort(x, kind="stable"))
        # real dtype-max values must survive next to the padding sentinel
        y = np.where(
            rng.random(n) < 0.5, np.iinfo(np.int32).max, 0
        ).astype(np.int32)
        got = np.asarray(
            sharded_sort_host(jnp.asarray(y), strategy="exchange", mesh=mesh)
        )
        np.testing.assert_array_equal(got, np.sort(y, kind="stable"))
    print(f"uneven sizes {sizes} via sharded_sort_host: OK")


def _hlo_allgather_sizes(txt):
    """Element counts of every all-gather op output in an HLO dump."""
    return collective_op_sizes(txt, "all-gather")


def check_capacity_semantics(mesh, p):
    """Default capacity is exact even on adversarial (pre-sorted) data,
    where one (sender, receiver) segment is a whole N/p block; an
    undersized capacity truncates to sentinels (documented MoE-style
    dropping), it must never corrupt ordering silently."""
    w = 128
    x = np.arange(p * w, dtype=np.int32)  # pre-sorted: maximal skew

    def run(capacity):
        fn = shard_map(
            lambda s: sharded_sort(
                s, "x", strategy="exchange", capacity=capacity
            ),
            mesh=mesh,
            in_specs=(P("x"),),
            out_specs=P("x"),
        )
        return np.asarray(jax.jit(fn)(jnp.asarray(x)))

    np.testing.assert_array_equal(run(None), x)  # default: exact
    np.testing.assert_array_equal(run(w), x)  # explicit N/p: exact
    # Undersized capacity: each block keeps its first `capacity` elements
    # in order and zero-fills the dropped tail (MoE-style capacity drop).
    truncated = run(w // 2).reshape(p, w)
    want = np.zeros((p, w), np.int32)
    want[:, : w // 2] = (
        np.arange(p, dtype=np.int32)[:, None] * w
        + np.arange(w // 2, dtype=np.int32)[None, :]
    )
    np.testing.assert_array_equal(truncated, want)
    print("capacity semantics (exact default, documented truncation): OK")


def check_hlo_no_replication(mesh, p):
    """The traced exchange program never all-gathers the values."""
    n = p * 1024

    def lower(strategy):
        fn = shard_map(
            lambda s: sharded_sort(s, "x", strategy=strategy),
            mesh=mesh,
            in_specs=(P("x"),),
            out_specs=P("x"),
        )
        return (
            jax.jit(fn)
            .lower(jax.ShapeDtypeStruct((n,), jnp.int32))
            .compile()
            .as_text()
        )

    ex = lower("exchange")
    ex_sizes = _hlo_allgather_sizes(ex)
    assert all(el < n for _, el in ex_sizes), (
        f"exchange path must not all-gather anything N-sized: {ex_sizes}"
    )
    # metadata collectives are O(p^2) int32 scalars
    assert all(el <= 4 * p * p for _, el in ex_sizes), ex_sizes
    a2a = collective_op_sizes(ex, "all-to-all")
    assert a2a, "exchange path must use all_to_all"
    assert max(el for _, el in a2a) <= n, (
        f"the balanced all_to_all moves at most the (p, N/p) slots: {a2a}"
    )

    ag = lower("allgather")
    ag_sizes = _hlo_allgather_sizes(ag)
    assert any(el >= n for _, el in ag_sizes), (
        f"positive control: allgather path should gather N values: {ag_sizes}"
    )
    print(
        f"HLO: exchange all-gathers {ex_sizes} (all < N={n}), "
        f"allgather strategy gathers {max(el for _, el in ag_sizes)}: OK"
    )


def main():
    devs = jax.devices()
    assert len(devs) == 8, devs
    p = 8
    mesh = Mesh(np.array(devs), ("x",))
    rng = np.random.default_rng(0)

    check_splitters(mesh, p, rng)
    check_stability(mesh, p, rng)
    check_sort_strategies(mesh, p, rng)
    check_uneven(mesh, p, rng)
    check_capacity_semantics(mesh, p)
    check_hlo_no_replication(mesh, p)
    print("ALL OK")


if __name__ == "__main__":
    main()
