"""Tests for stable parallel merge (Algorithm 2) and the rank-merge."""

import numpy as np
import pytest
import jax.numpy as jnp
from _prop import given, settings, st

from repro.core import (
    merge_by_ranking,
    merge_equidistant,
    merge_lexicographic,
    merge_partitioned,
    partition_bounds,
    partition_sizes_equidistant,
)


def stable_merge_oracle(a, b):
    """NumPy oracle: stable merge == stable sort of concat([A, B])."""
    return np.sort(np.concatenate([a, b]), kind="stable")


def stable_merge_tagged_oracle(a, b):
    """Origin-tagged oracle to verify stability, not just values:
    returns (values, origin) where origin 0=A, 1=B, stably merged."""
    keys = np.concatenate([a, b])
    origin = np.concatenate([np.zeros(len(a), np.int8), np.ones(len(b), np.int8)])
    order = np.argsort(keys, kind="stable")  # ties keep concat order: A first
    return keys[order], origin[order]


def rand_sorted(rng, size, lo=0, hi=20):
    return np.sort(rng.integers(lo, hi, size)).astype(np.int32)


@pytest.mark.parametrize("m,n", [(16, 16), (7, 100), (100, 7), (1, 1), (255, 257)])
def test_merge_by_ranking_values(m, n):
    rng = np.random.default_rng(0)
    a, b = rand_sorted(rng, m), rand_sorted(rng, n)
    got = np.asarray(merge_by_ranking(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, stable_merge_oracle(a, b))


@pytest.mark.parametrize("p", [1, 2, 3, 8, 16, 31])
@pytest.mark.parametrize("m,n", [(64, 64), (5, 123), (123, 5), (97, 31)])
def test_merge_partitioned_values(p, m, n):
    rng = np.random.default_rng(p * 1000 + m + n)
    a, b = rand_sorted(rng, m), rand_sorted(rng, n)
    got = np.asarray(merge_partitioned(jnp.asarray(a), jnp.asarray(b), p=p))
    np.testing.assert_array_equal(got, stable_merge_oracle(a, b))


def test_merge_stability_tagged():
    """Verify A-before-B on ties by merging values with origin payload.

    Encode each element as value*2 + origin so equal input keys become
    distinguishable in the output while preserving order.
    """
    rng = np.random.default_rng(3)
    a = np.sort(rng.integers(0, 4, 50)).astype(np.int64)
    b = np.sort(rng.integers(0, 4, 60)).astype(np.int64)
    # merge on the raw keys; afterwards check positions of tagged copies
    got = np.asarray(
        merge_partitioned(jnp.asarray(a * 2), jnp.asarray(b * 2 + 1), p=7)
    )
    vals, origin = got // 2, got % 2
    want_vals, want_origin = stable_merge_tagged_oracle(a, b)
    np.testing.assert_array_equal(vals, want_vals)
    np.testing.assert_array_equal(origin, want_origin)


def test_partition_bounds_balance():
    """Proposition 2: block sizes differ by at most one."""
    for total, p in [(1000, 7), (1024, 16), (999, 512), (5, 8)]:
        bounds = np.asarray(partition_bounds(total, p))
        sizes = np.diff(bounds)
        assert sizes.sum() == total
        assert sizes.max() - sizes.min() <= 1


def test_equidistant_baseline_imbalance():
    """The classic partition CAN be ~2x imbalanced; co-rank never is.

    Adversarial input: all of A less than all of B makes splitter
    cross-ranks collapse, giving empty and maximal segments.
    """
    m = n = 1024
    p = 8
    a = jnp.arange(m, dtype=jnp.int32)
    b = jnp.arange(m, 2 * m, dtype=jnp.int32)
    sizes = np.asarray(partition_sizes_equidistant(a, b, p))
    ideal = (m + n) / (2 * p)
    assert sizes.max() >= 1.9 * ideal  # factor-2 imbalance realised
    # and the paper's partition on the same input is perfectly balanced:
    bounds = np.diff(np.asarray(partition_bounds(m + n, 2 * p)))
    assert bounds.max() - bounds.min() <= 1


@pytest.mark.parametrize("m,n", [(64, 64), (13, 200)])
def test_baseline_merges_correct(m, n):
    rng = np.random.default_rng(9)
    a, b = rand_sorted(rng, m), rand_sorted(rng, n)
    want = stable_merge_oracle(a, b)
    got_eq = np.asarray(merge_equidistant(jnp.asarray(a), jnp.asarray(b), p=4))
    got_lex = np.asarray(merge_lexicographic(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got_eq, want)
    np.testing.assert_array_equal(got_lex, want)


@settings(max_examples=100, deadline=None)
@given(
    st.lists(st.integers(-9, 9), min_size=1, max_size=80),
    st.lists(st.integers(-9, 9), min_size=1, max_size=80),
    st.integers(1, 12),
)
def test_merge_partitioned_property(xs, ys, p):
    a = np.sort(np.asarray(xs, np.int32))
    b = np.sort(np.asarray(ys, np.int32))
    got = np.asarray(merge_partitioned(jnp.asarray(a), jnp.asarray(b), p=p))
    np.testing.assert_array_equal(got, stable_merge_oracle(a, b))


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.floats(-1e3, 1e3, allow_nan=False, allow_subnormal=False, width=32),
        min_size=1,
        max_size=60,
    ),
    st.lists(
        st.floats(-1e3, 1e3, allow_nan=False, allow_subnormal=False, width=32),
        min_size=1,
        max_size=60,
    ),
)
def test_merge_by_ranking_floats(xs, ys):
    a = np.sort(np.asarray(xs, np.float32))
    b = np.sort(np.asarray(ys, np.float32))
    got = np.asarray(merge_by_ranking(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, stable_merge_oracle(a, b))
