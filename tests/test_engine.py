"""Cross-layer equivalence sweep: one engine, identical cuts everywhere.

Every instantiation of ``repro.core.engine`` — device ``co_rank`` /
``co_rank_kway``, host-planner ``co_rank_kway_host``, Pallas
``merge_kway_pallas`` (interpret), and the 8-device collective searches
(subprocess lane) — must agree bit-for-bit with the engine-independent
brute-force oracle on the shared cases in ``_engine_cases``.
"""

import os
import pathlib
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from _engine_cases import (
    kway_cases,
    oracle_cuts,
    oracle_pairwise,
    pairwise_cases,
    rank_sweep,
)
from repro.core.corank import co_rank
from repro.core.kway import co_rank_kway_batch
from repro.external.planner import co_rank_kway_host

REPO = pathlib.Path(__file__).resolve().parents[1]

KWAY_CASES = kway_cases(4)
CASE_IDS = [name for name, _, _ in KWAY_CASES]


@pytest.mark.parametrize("name,a,b", pairwise_cases(),
                         ids=[c[0] for c in pairwise_cases()])
def test_pairwise_matches_oracle(name, a, b):
    m, n = len(a), len(b)
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    for i in rank_sweep(m + n):
        res = co_rank(i, aj, bj)
        assert (int(res.j), int(res.k)) == oracle_pairwise(a, b, i), (
            name, i, int(res.j), int(res.k))


@pytest.mark.parametrize("name,runs,lengths", KWAY_CASES, ids=CASE_IDS)
def test_kway_device_matches_oracle(name, runs, lengths):
    total = int(lengths.sum())
    sweep = rank_sweep(total)
    cuts = np.asarray(
        co_rank_kway_batch(
            jnp.asarray(sweep, jnp.int32),
            jnp.asarray(runs),
            jnp.asarray(lengths),
        )
    )
    for row, i in zip(cuts, sweep):
        np.testing.assert_array_equal(
            row, oracle_cuts(runs, lengths, i), err_msg=f"{name} i={i}"
        )


@pytest.mark.parametrize("name,runs,lengths", KWAY_CASES, ids=CASE_IDS)
def test_kway_host_planner_matches_device(name, runs, lengths):
    total = int(lengths.sum())
    ragged = [runs[r, : lengths[r]] for r in range(runs.shape[0])]
    device = np.asarray(
        co_rank_kway_batch(
            jnp.asarray(rank_sweep(total), jnp.int32),
            jnp.asarray(runs),
            jnp.asarray(lengths),
        )
    )
    for row, i in zip(device, rank_sweep(total)):
        host = co_rank_kway_host(i, ragged)
        np.testing.assert_array_equal(host, row, err_msg=f"{name} i={i}")
        np.testing.assert_array_equal(
            host, oracle_cuts(runs, lengths, i), err_msg=f"{name} i={i}"
        )


@pytest.mark.parametrize("name,runs,lengths", KWAY_CASES, ids=CASE_IDS)
def test_pallas_interpret_bitexact(name, runs, lengths):
    """Interpret-mode kernel merge == brute-force stable order, payload
    permutation included (the payload pins the tie order exactly)."""
    from repro.kernels.merge import merge_kway_pallas

    k, w = runs.shape
    total = int(lengths.sum())
    ids = (np.arange(k * w, dtype=np.int32)).reshape(k, w)
    keys, vals = merge_kway_pallas(
        jnp.asarray(runs),
        jnp.asarray(ids),
        lengths=jnp.asarray(lengths),
        tile=16,
        interpret=True,
    )
    run_ids = np.repeat(np.arange(k), w)
    offs = np.tile(np.arange(w), k)
    real = offs < np.asarray(lengths)[run_ids]
    order = np.lexsort((offs[real], run_ids[real], runs.ravel()[real]))
    np.testing.assert_array_equal(
        np.asarray(keys)[:total], runs.ravel()[real][order], err_msg=name
    )
    np.testing.assert_array_equal(
        np.asarray(vals)[:total],
        ids.ravel()[real][order],
        err_msg=f"{name}: payload permutation (tie order) drifted",
    )


@pytest.mark.slow
def test_distributed_cuts_eight_devices():
    """Subprocess lane: the collective searches on 8 fake devices return
    the same cuts as the device tier on the shared cases (k = p = 8)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "_engine_check.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    assert "ALL OK" in proc.stdout
