"""Single-device tests for the kernel dispatch policy, the fanout
plumbing, and the exchange subsystem's host-facing surfaces."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.kway import merge_kway_ranked
from repro.data.pipeline import DataConfig, bucket_by_length
from repro.distributed import slot_transpose
from repro.distributed.api import distributed_merge, sharded_merge_kway
from repro.kernels import ops
from repro.serving.sampling import sample_topk, sample_topp


# --- kernels/ops.py dispatch policy ----------------------------------------


def test_default_backend_auto_matches_platform():
    want = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert ops.default_backend() == want


def test_backend_env_override(monkeypatch):
    monkeypatch.setenv(ops.BACKEND_ENV_VAR, "pallas")
    assert ops.default_backend() == "pallas"
    monkeypatch.setenv(ops.BACKEND_ENV_VAR, "xla")
    assert ops.default_backend() == "xla"
    monkeypatch.setenv(ops.BACKEND_ENV_VAR, "AUTO")
    assert ops.default_backend() in ("pallas", "xla")
    # the stable_sort escape hatch is reachable through the env too
    monkeypatch.setenv(ops.BACKEND_ENV_VAR, "xla_native")
    assert ops.default_backend() == "xla_native"
    monkeypatch.setenv(ops.BACKEND_ENV_VAR, "cuda")
    with pytest.raises(ValueError, match="REPRO_MERGE_BACKEND"):
        ops.default_backend()


def test_pallas_backend_interpret_fallback():
    """Off-TPU, backend='pallas' silently interprets; explicitly asking
    for a compiled kernel (interpret=False) is an error, not a
    mis-dispatch."""
    runs = jnp.sort(
        jnp.arange(4 * 256, dtype=jnp.int32).reshape(4, 256) % 97, axis=1
    )
    want = np.sort(np.asarray(runs).reshape(-1), kind="stable")
    got = ops.stable_merge_kway(runs, backend="pallas", tile=256)
    np.testing.assert_array_equal(np.asarray(got), want)
    if jax.default_backend() != "tpu":
        with pytest.raises(ValueError, match="interpret"):
            ops.stable_merge_kway(
                runs, backend="pallas", tile=256, interpret=False
            )


# --- fanout plumbing --------------------------------------------------------


def test_model_config_has_fanout_default_zero():
    cfg = ModelConfig(
        name="t", family="dense", n_layers=1, d_model=8, n_heads=2,
        n_kv_heads=2, d_ff=16, vocab=32,
    )
    assert cfg.fanout == 0


@pytest.mark.parametrize("fanout", [0, 2, 4, 8])
def test_sample_topk_fanout_invariant(fanout):
    key = jax.random.key(0)
    logits = jax.random.normal(jax.random.key(1), (3, 128))
    base = sample_topk(key, logits, k=16)
    got = sample_topk(key, logits, k=16, fanout=fanout)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


@pytest.mark.parametrize("fanout", [0, 2, 8])
def test_sample_topp_fanout_invariant(fanout):
    key = jax.random.key(2)
    logits = jax.random.normal(jax.random.key(3), (2, 128))
    base = sample_topp(key, logits, p=0.9, k=32)
    got = sample_topp(key, logits, p=0.9, k=32, fanout=fanout)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


def test_bucket_by_length_fanout_invariant():
    rng = np.random.default_rng(0)
    lengths = rng.integers(1, 100, 257)
    base = bucket_by_length(lengths)
    for fanout in (2, 4, 8):
        np.testing.assert_array_equal(
            base, bucket_by_length(lengths, fanout=fanout)
        )
    assert DataConfig(vocab=8, seq_len=16, batch=1, fanout=2).fanout == 2


# --- exchange subsystem surfaces -------------------------------------------


def test_strategy_validation_errors():
    a = jnp.arange(8)
    with pytest.raises(ValueError, match="allgather"):
        distributed_merge(a, a, "x", strategy="bogus")
    with pytest.raises(ValueError, match="exchange"):
        sharded_merge_kway(a, "x", strategy="bogus")


def test_slot_transpose_roundtrip():
    x = jnp.arange(2 * 3 * 4 * 5, dtype=jnp.float32).reshape(2, 3, 4, 5)
    y = slot_transpose(x)
    assert y.shape == (3, 2, 4, 5)
    np.testing.assert_array_equal(
        np.asarray(slot_transpose(y)), np.asarray(x)
    )


def test_merge_kway_ranked_lengths_sideband_matches_exchange_layout():
    """The receiver-side ragged merge: head-packed segments + sentinel
    tails + lengths sideband reproduce the stable merge of the real
    elements (dtype-max values included)."""
    rng = np.random.default_rng(1)
    p, cap = 4, 16
    segs = np.full((p, cap), np.iinfo(np.int32).max, np.int32)
    lengths = np.array([16, 0, 7, 9])
    parts = []
    for r in range(p):
        seg = np.sort(rng.integers(0, 5, lengths[r])).astype(np.int32)
        segs[r, : lengths[r]] = seg
        parts.append(seg)
    want = np.sort(np.concatenate(parts), kind="stable")
    got = merge_kway_ranked(
        jnp.asarray(segs),
        lengths=jnp.asarray(lengths),
        out_len=int(lengths.sum()),
    )
    np.testing.assert_array_equal(np.asarray(got), want)
