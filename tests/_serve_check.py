"""End-to-end continuous-batching smoke decode, run in a subprocess.

Invoked by tests/test_serving.py; exits nonzero on any failure.  Serves
a staggered-arrival request mix through the full stack — launcher-style
DecodeEngine on the qwen3-0.6b smoke config with obs metrics captured —
and checks the serving acceptance criteria:

* every submitted request finishes with exactly its ``max_new_tokens``;
* ``serve.active_slots`` never exceeds the pool capacity on any step
  (read back from the captured metric stream, not engine internals);
* admissions + completions reconcile: counters sum to the request
  count, and the scheduler/pool invariants hold at exit;
* the per-step merge-cut geometry recorded by
  ``serve.topk_merge_rounds`` is constant across steps (the tournament
  never grows with occupancy);
* a second engine run with the same seed reproduces every token stream
  byte-for-byte (the serving determinism contract).
"""

import sys

import numpy as np
import jax

from repro import obs
from repro.configs.registry import ARCHS, smoke_config
from repro.models.transformer import init_params
from repro.serving import DecodeEngine, Request

CAPACITY = 3
N_REQUESTS = 7
SEED = 123


def _arrivals(cfg):
    rng = np.random.default_rng(42)
    return [
        (2 * i,
         Request(i, rng.integers(1, cfg.vocab, 2 + i % 3, dtype=np.int32),
                 3 + i % 4))
        for i in range(N_REQUESTS)
    ]


def _serve(cfg, params):
    eng = DecodeEngine(cfg, params, max_len=32, max_batch=CAPACITY,
                       queue_depth=4, sampler="topk", top_k=8, seed=SEED)
    results = eng.run(max_steps=400, arrivals=_arrivals(cfg))
    eng.scheduler.check_invariants()
    eng.pool.check_invariants()
    return eng, results


def main() -> int:
    cfg = smoke_config(ARCHS["qwen3-0.6b"])
    params, _ = init_params(cfg, jax.random.key(0))

    with obs.capture() as records:
        eng, results = _serve(cfg, params)

    arrivals = _arrivals(cfg)
    assert sorted(results) == [r.rid for _, r in arrivals], (
        f"requests lost: served {sorted(results)}"
    )
    for _, req in arrivals:
        got = len(results[req.rid])
        assert got == req.max_new_tokens, (
            f"rid {req.rid}: {got} tokens != {req.max_new_tokens}"
        )
    print(f"ok: {len(results)} requests finished in {eng.steps} steps")

    slots = [r for r in records if r["metric"] == "serve.active_slots"]
    assert slots, "no serve.active_slots records captured"
    peak = max(r["value"] for r in slots)
    assert peak <= CAPACITY, (
        f"active_slots peaked at {peak} > capacity {CAPACITY}"
    )
    assert peak == CAPACITY, (
        f"staggered mix never saturated the pool (peak {peak}); "
        f"the overlap scenario under test did not occur"
    )
    print(f"ok: active_slots <= capacity on all {len(slots)} steps "
          f"(peak {peak})")

    admitted = sum(r["value"] for r in records
                   if r["metric"] == "serve.admitted")
    completed = sum(r["value"] for r in records
                    if r["metric"] == "serve.completed")
    recycled = sum(r["value"] for r in records
                   if r["metric"] == "serve.slots_recycled")
    assert admitted == completed == recycled == N_REQUESTS, (
        f"lifecycle counters disagree: admitted {admitted}, "
        f"completed {completed}, recycled {recycled}"
    )
    print("ok: admission/completion/recycle counters reconcile")

    rounds = {r["value"] for r in records
              if r["metric"] == "serve.topk_merge_rounds"}
    assert len(rounds) <= 1, (
        f"merge-cut count varied across steps: {sorted(rounds)}"
    )
    print(f"ok: constant tournament geometry (rounds={sorted(rounds)})")

    _, results2 = _serve(cfg, params)
    assert results == results2, "token streams not reproducible"
    print("ok: byte-identical streams on rerun")
    return 0


if __name__ == "__main__":
    sys.exit(main())
