"""Out-of-core external sort (repro.external): bit-exactness against the
stable in-memory oracle, spill/merge stability, crash-resume replay,
device-residency bounds, and the ops.merge_window dispatch surface.

Everything runs on the CPU harness: "device memory" is the configured
chunk size, and the interesting properties (exact stable order across
spill round-trips, O(fanout * window) merge residency, idempotent window
replay) are backend-independent.
"""

import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro import obs
from repro.core.kway import co_rank_kway
from repro.core.mergesort import sentinel_max
from repro.data.pipeline import bucket_by_length
from repro.external import planner
from repro.external.api import external_argsort, external_sort
from repro.external.runs import MANIFEST_NAME, RunSet
from repro.kernels import ops


def ref_order(keys: np.ndarray) -> np.ndarray:
    return np.argsort(keys, kind="stable")


def run_external(keys, vals, workdir, **kw):
    got = external_sort(keys, vals, workdir=workdir, **kw)
    if vals is None:
        return np.asarray(got)
    return np.asarray(got[0]), np.asarray(got[1])


# --- bit-exactness vs np.argsort(kind="stable") -----------------------------


def test_duplicate_heavy_multi_pass_stability(tmp_path):
    """Few distinct keys, enough chunks for three merge passes: payload
    order must survive every spill round-trip exactly."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 4, 613).astype(np.int32)
    vals = np.arange(613, dtype=np.int32)
    sk, sv = run_external(
        keys, vals, str(tmp_path), chunk=67, fanout=3, window=23
    )
    order = ref_order(keys)
    np.testing.assert_array_equal(sk, keys[order])
    np.testing.assert_array_equal(sv, order)  # stability, not just keys


def test_float_extremes(tmp_path):
    f = np.finfo(np.float32)
    base = np.array(
        [np.inf, -np.inf, f.max, f.min, 0.0, -0.0, 1.5, -1.5, f.tiny],
        np.float32,
    )
    rng = np.random.default_rng(1)
    keys = base[rng.integers(0, len(base), 500)]
    vals = np.arange(500, dtype=np.int32)
    sk, sv = run_external(
        keys, vals, str(tmp_path), chunk=61, fanout=4, window=16
    )
    order = ref_order(keys)
    np.testing.assert_array_equal(sk, keys[order])
    np.testing.assert_array_equal(sv, order)


def test_int32_max_keys_not_confused_with_padding(tmp_path):
    """Real INT32_MAX keys collide with the staging sentinel; the lengths
    sideband (not sentinel ordering) must keep them exact."""
    hi = np.iinfo(np.int32).max
    rng = np.random.default_rng(2)
    keys = rng.choice(
        np.array([hi, hi - 1, 0, -5], np.int32), 400
    ).astype(np.int32)
    vals = np.arange(400, dtype=np.int32)
    sk, sv = run_external(
        keys, vals, str(tmp_path), chunk=53, fanout=3, window=11
    )
    order = ref_order(keys)
    np.testing.assert_array_equal(sk, keys[order])
    np.testing.assert_array_equal(sv, order)


@pytest.mark.parametrize("direction", ["asc", "desc"])
def test_presorted_inputs(tmp_path, direction):
    keys = np.arange(300, dtype=np.int32)
    if direction == "desc":
        keys = keys[::-1].copy()
    vals = np.arange(300, dtype=np.int32)
    sk, sv = run_external(
        keys, vals, str(tmp_path), chunk=47, fanout=2, window=13
    )
    order = ref_order(keys)
    np.testing.assert_array_equal(sk, keys[order])
    np.testing.assert_array_equal(sv, order)


def test_keys_only_and_edge_sizes(tmp_path):
    rng = np.random.default_rng(3)
    keys = rng.integers(-50, 50, 257).astype(np.int32)
    got = run_external(keys, None, str(tmp_path / "a"), chunk=31, fanout=2)
    np.testing.assert_array_equal(got, np.sort(keys, kind="stable"))
    # single-chunk passthrough (no merge pass at all)
    got = run_external(keys, None, str(tmp_path / "b"), chunk=1024)
    np.testing.assert_array_equal(got, np.sort(keys, kind="stable"))
    # empty and singleton inputs
    empty = run_external(
        np.empty(0, np.int32), None, str(tmp_path / "c"), chunk=8
    )
    assert len(empty) == 0
    one = run_external(np.array([7], np.int32), None,
                       str(tmp_path / "d"), chunk=8)
    np.testing.assert_array_equal(one, [7])


def test_external_argsort_matches_np(tmp_path):
    rng = np.random.default_rng(4)
    keys = rng.integers(0, 9, 321).astype(np.int32)
    order = external_argsort(
        keys, chunk=40, fanout=3, workdir=str(tmp_path)
    )
    np.testing.assert_array_equal(np.asarray(order), ref_order(keys))


# --- crash-resume -----------------------------------------------------------


class Boom(RuntimeError):
    pass


def test_crash_resume_mid_merge_is_bit_exact(tmp_path):
    """Kill the sort after 3 durable windows; the resumed run replays
    only the remaining windows and the output is identical."""
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 100, 700).astype(np.int32)
    vals = np.arange(700, dtype=np.int32)
    kw = dict(chunk=97, fanout=3, window=29, cleanup=False)

    full = []
    run_external(keys, vals, str(tmp_path / "full"), **kw,
                 on_window=lambda *a: full.append(a))

    crashed = []

    def crash(p, g, w):
        crashed.append((p, g, w))
        if len(crashed) == 3:
            raise Boom

    wd = str(tmp_path / "crashy")
    with pytest.raises(Boom):
        external_sort(keys, vals, workdir=wd, on_window=crash, **kw)

    resumed = []
    sk, sv = run_external(keys, vals, wd, **kw,
                          on_window=lambda *a: resumed.append(a))
    order = ref_order(keys)
    np.testing.assert_array_equal(sk, keys[order])
    np.testing.assert_array_equal(sv, order)
    # the 3 windows durable before the crash are not re-merged
    assert len(resumed) == len(full) - 3
    assert resumed == full[3:]


def test_resume_rejects_changed_input(tmp_path):
    keys = np.arange(100, dtype=np.int32)[::-1].copy()
    kw = dict(chunk=16, fanout=2, cleanup=False)
    run_external(keys, None, str(tmp_path), **kw)
    changed = keys + 1
    got = run_external(changed, None, str(tmp_path), **kw)
    np.testing.assert_array_equal(got, np.sort(changed, kind="stable"))


def test_torn_manifest_restarts_cleanly(tmp_path):
    (tmp_path / MANIFEST_NAME).write_text('{"torn', encoding="ascii")
    assert RunSet.load(str(tmp_path)) is None
    keys = np.array([3, 1, 2, 0], np.int32)
    got = run_external(keys, None, str(tmp_path), chunk=2)
    np.testing.assert_array_equal(got, [0, 1, 2, 3])


# --- device residency bound -------------------------------------------------


def test_device_residency_bounded(tmp_path):
    """On a >= 4x-chunk input, the merge phase never stages more than two
    (k, window) double-buffered inputs plus one output window, and the
    spill phase never exceeds one chunk — the O(fanout * window) claim."""
    rng = np.random.default_rng(6)
    chunk, fanout, window = 512, 3, 64
    n = 4 * chunk + 52
    keys = rng.integers(0, 1 << 20, n).astype(np.int32)
    vals = np.arange(n, dtype=np.int32)
    with obs.capture() as records:
        sk, sv = run_external(
            keys, vals, str(tmp_path),
            chunk=chunk, fanout=fanout, window=window,
        )
    order = ref_order(keys)
    np.testing.assert_array_equal(sk, keys[order])
    np.testing.assert_array_equal(sv, order)

    res = [r for r in records
           if r["metric"] == "external.device_resident_bytes"]
    by_phase = {}
    for r in res:
        by_phase.setdefault(r["labels"]["phase"], []).append(r["value"])
    itm = 4 + 4  # int32 keys + int32 payload
    assert max(by_phase["chunk_sort"]) <= chunk * itm
    # two staged (k, window) inputs + lengths sidebands + one output window
    bound = 2 * (fanout * window * itm + fanout * 4) + window * itm
    assert max(by_phase["merge"]) <= bound
    assert bound <= chunk * itm  # the sweep's windows fit inside one chunk

    # the planner only ever holds the k boundary probes
    probes = [r for r in records
              if r["metric"] == "external.resident_boundary_elems"]
    assert probes and all(r["value"] <= fanout for r in probes)
    passes = [r["value"] for r in records
              if r["metric"] == "external.merge_passes"]
    assert passes and passes[-1] >= 2  # 9 runs at fanout 3: multi-pass


# --- planner vs on-device co-rank -------------------------------------------


def test_host_corank_matches_core(tmp_path):
    rng = np.random.default_rng(7)
    k, w = 5, 64
    lengths = np.array([64, 0, 17, 33, 1], np.int64)
    segs = [np.sort(rng.integers(0, 9, int(l))).astype(np.int32)
            for l in lengths]
    padded = np.full((k, w), sentinel_max(np.dtype(np.int32)), np.int32)
    for q, s in enumerate(segs):
        padded[q, : len(s)] = s
    total = int(lengths.sum())
    for i in [0, 1, 7, total // 3, total // 2, total - 1, total]:
        host = planner.co_rank_kway_host(i, segs, lengths)
        dev = np.asarray(
            co_rank_kway(i, jnp.asarray(padded), jnp.asarray(lengths))
        )
        np.testing.assert_array_equal(host, dev, err_msg=f"rank {i}")
        assert host.sum() == i


def test_window_ranks_cover_input():
    assert planner.window_ranks(10, 4) == [(0, 4), (4, 8), (8, 10)]
    assert planner.window_ranks(8, 4) == [(0, 4), (4, 8)]
    assert planner.window_ranks(0, 4) == []


# --- ops.merge_window dispatch (satellite: REPRO_MERGE_BACKEND) -------------


def _ragged_case(seed, k=3, w=40, dtype=np.int32, hi=None):
    rng = np.random.default_rng(seed)
    lengths = rng.integers(0, w + 1, k)
    lengths[0] = w  # at least one full row
    pad = sentinel_max(np.dtype(dtype))
    runs = np.full((k, w), pad, dtype)
    vals = np.zeros((k, w), np.int32)
    nxt = 0
    parts = []
    for q in range(k):
        seg = np.sort(
            rng.integers(0, hi if hi is not None else 9, lengths[q])
        ).astype(dtype)
        runs[q, : lengths[q]] = seg
        vals[q, : lengths[q]] = np.arange(nxt, nxt + lengths[q])
        parts.append((seg, vals[q, : lengths[q]].copy()))
        nxt += int(lengths[q])
    ks = np.concatenate([p[0] for p in parts])
    vs = np.concatenate([p[1] for p in parts])
    order = np.argsort(ks, kind="stable")
    return runs, vals, lengths.astype(np.int32), ks[order], vs[order]


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_merge_window_backends_bit_exact(backend):
    runs, vals, lengths, want_k, want_v = _ragged_case(8)
    total = int(lengths.sum())
    gk, gv = ops.merge_window(
        jnp.asarray(runs), jnp.asarray(vals), jnp.asarray(lengths),
        out_len=total, backend=backend, tile=128,
    )
    np.testing.assert_array_equal(np.asarray(gk), want_k)
    np.testing.assert_array_equal(np.asarray(gv), want_v)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_merge_window_dtype_max_keys(backend):
    """Real dtype-max keys among sentinel padding: the lengths sideband
    must disambiguate them on every backend."""
    hi = np.iinfo(np.int32).max
    runs, vals, lengths, want_k, want_v = _ragged_case(9, hi=hi)
    runs[runs < hi - 2] = hi  # saturate most keys at the sentinel value
    # rebuild the oracle after saturation
    parts_k, parts_v = [], []
    for q in range(len(lengths)):
        seg = np.sort(runs[q, : lengths[q]])
        runs[q, : lengths[q]] = seg
        parts_k.append(seg)
        parts_v.append(vals[q, : lengths[q]])
    ks, vs = np.concatenate(parts_k), np.concatenate(parts_v)
    order = np.argsort(ks, kind="stable")
    total = int(lengths.sum())
    gk, gv = ops.merge_window(
        jnp.asarray(runs), jnp.asarray(vals), jnp.asarray(lengths),
        out_len=total, backend=backend, tile=128,
    )
    np.testing.assert_array_equal(np.asarray(gk), ks[order])
    np.testing.assert_array_equal(np.asarray(gv), vs[order])


def test_merge_window_invalid_backend_raises():
    runs = jnp.zeros((2, 8), jnp.int32)
    with pytest.raises(ValueError, match="backend"):
        ops.merge_window(runs, backend="cuda")


def test_merge_window_honors_backend_env(monkeypatch):
    """The external merge path reads REPRO_MERGE_BACKEND at trace time:
    a bogus value must fail the dispatch, a valid one must merge
    (fresh shapes per setting defeat the jit cache)."""
    runs, vals, lengths, want_k, want_v = _ragged_case(10, w=37)
    total = int(lengths.sum())
    monkeypatch.setenv(ops.BACKEND_ENV_VAR, "cuda")
    with pytest.raises(ValueError, match=ops.BACKEND_ENV_VAR):
        ops.merge_window(
            jnp.asarray(runs), jnp.asarray(vals), jnp.asarray(lengths),
            out_len=total,
        )
    monkeypatch.setenv(ops.BACKEND_ENV_VAR, "pallas")
    runs2, vals2, lengths2, want_k2, want_v2 = _ragged_case(10, w=39)
    total2 = int(lengths2.sum())
    gk, gv = ops.merge_window(
        jnp.asarray(runs2), jnp.asarray(vals2), jnp.asarray(lengths2),
        out_len=total2, tile=128,
    )
    np.testing.assert_array_equal(np.asarray(gk), want_k2)
    np.testing.assert_array_equal(np.asarray(gv), want_v2)


def test_external_sort_through_pallas_backend(tmp_path):
    """End-to-end spill+merge with every window on the pallas kernel."""
    rng = np.random.default_rng(11)
    keys = rng.integers(0, 50, 300).astype(np.int32)
    vals = np.arange(300, dtype=np.int32)
    sk, sv = run_external(
        keys, vals, str(tmp_path),
        chunk=64, fanout=2, window=32, backend="pallas",
    )
    order = ref_order(keys)
    np.testing.assert_array_equal(sk, keys[order])
    np.testing.assert_array_equal(sv, order)


# --- pipeline integration ---------------------------------------------------


def test_bucket_by_length_external_matches_inmem(tmp_path):
    rng = np.random.default_rng(12)
    lengths = rng.integers(1, 100, 257)
    base = bucket_by_length(lengths)
    got = bucket_by_length(
        lengths, external_threshold=64, external_workdir=str(tmp_path)
    )
    np.testing.assert_array_equal(base, got)
    # below the threshold the in-memory path runs (workdir untouched)
    small = bucket_by_length(
        lengths[:32], external_threshold=64,
        external_workdir=str(tmp_path / "unused"),
    )
    np.testing.assert_array_equal(small, bucket_by_length(lengths[:32]))
    assert not os.path.exists(str(tmp_path / "unused"))


# --- large sweep ------------------------------------------------------------


@pytest.mark.slow
def test_large_multi_pass_sweep(tmp_path):
    rng = np.random.default_rng(13)
    n = 200_000
    keys = rng.integers(np.iinfo(np.int32).min, np.iinfo(np.int32).max, n,
                        dtype=np.int64).astype(np.int32)
    vals = np.arange(n, dtype=np.int32)
    sk, sv = run_external(
        keys, vals, str(tmp_path), chunk=8192, fanout=4
    )
    order = ref_order(keys)
    np.testing.assert_array_equal(sk, keys[order])
    np.testing.assert_array_equal(sv, order)
