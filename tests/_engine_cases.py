"""Shared oracle cases for the cross-layer co-rank equivalence sweep.

Every instantiation of the one co-rank engine (``repro.core.engine``) —
device (``core.kway`` / ``core.corank``), distributed
(``distributed.splitters``, 8 fake devices in a subprocess), host
planner (``external.planner``) and the Pallas kernel
(``kernels.merge``, interpret mode) — must return bit-identical cuts on
these cases.  The cases deliberately stress the places where the five
former transcriptions used to drift:

* duplicate-heavy keys (the stability tie-break carries the answer);
* ±inf floats (comparison edge values);
* real int32 dtype-max elements coexisting with dtype-max padding;
* pre-sorted inputs (degenerate cuts: whole runs taken in order);
* ragged / zero-length runs behind the ``lengths`` sideband.

The oracle is engine-independent: a numpy stable ``lexsort`` over
``(value, run, offset)`` — the paper's definition of the stable k-way
merge order, computed by brute force.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "kway_cases",
    "oracle_cuts",
    "oracle_pairwise",
    "pairwise_cases",
    "rank_sweep",
]


def _pad_rows(rows, w, fill):
    """Stack ragged sorted rows into a sorted-over-full-width (k, w)."""
    k = len(rows)
    out = np.full((k, w), fill, dtype=np.result_type(fill, *rows))
    for r, row in enumerate(rows):
        out[r, : len(row)] = row
    return out


def kway_cases(k: int):
    """List of ``(name, runs, lengths)``: ``runs`` is ``(k, w)`` with every
    row sorted over its full width; ``lengths`` is int32 ``(k,)`` real
    lengths (always explicit, so every tier exercises its sideband)."""
    rng = np.random.default_rng(1234 + k)
    cases = []

    # Duplicate-heavy: tiny key universe, every cut decided by ties.
    w = 32
    runs = np.sort(rng.integers(0, 4, (k, w)), axis=1).astype(np.int32)
    cases.append(("dup_heavy", runs, np.full(k, w, np.int32)))

    # ±inf floats: infinities as *real* elements, +inf also the padding.
    w = 24
    rows = []
    lens = []
    for r in range(k):
        n = int(rng.integers(8, w + 1))
        body = rng.normal(size=n - 2).astype(np.float32)
        row = np.sort(
            np.concatenate([[-np.inf], body, [np.inf]]).astype(np.float32)
        )
        rows.append(row)
        lens.append(n)
    cases.append(
        ("pm_inf", _pad_rows(rows, w, np.float32(np.inf)),
         np.asarray(lens, np.int32))
    )

    # Real int32 dtype-max elements + dtype-max padding on ragged rows.
    w = 20
    imax = np.iinfo(np.int32).max
    rows = []
    lens = []
    for r in range(k):
        n = int(rng.integers(4, w + 1))
        row = np.sort(rng.integers(imax - 3, imax + 1, n)).astype(np.int32)
        rows.append(row)
        lens.append(n)
    cases.append(
        ("dtype_max", _pad_rows(rows, w, np.int32(imax)),
         np.asarray(lens, np.int32))
    )

    # Pre-sorted: the concatenation is already globally sorted.
    w = 16
    flat = np.sort(rng.integers(-100, 100, k * w)).astype(np.int32)
    cases.append(
        ("pre_sorted", flat.reshape(k, w), np.full(k, w, np.int32))
    )

    # Ragged with a zero-length run and heavy duplicates.
    w = 28
    rows = []
    lens = []
    for r in range(k):
        n = 0 if r == k // 2 else int(rng.integers(1, w + 1))
        rows.append(np.sort(rng.integers(0, 6, n)).astype(np.int32))
        lens.append(n)
    cases.append(
        ("ragged_zero", _pad_rows(rows, w, np.int32(np.iinfo(np.int32).max)),
         np.asarray(lens, np.int32))
    )

    return cases


def oracle_cuts(runs: np.ndarray, lengths: np.ndarray, i: int) -> np.ndarray:
    """Brute-force stable cut vector J(i): int64 (k,).

    Stable k-way merge order is lexicographic on (value, run, offset);
    J(i)_r counts run r's elements among the first i merged.
    """
    k, w = runs.shape
    run_ids = np.repeat(np.arange(k), w)
    offs = np.tile(np.arange(w), k)
    real = offs < np.asarray(lengths)[run_ids]
    vals, run_ids, offs = runs.ravel()[real], run_ids[real], offs[real]
    order = np.lexsort((offs, run_ids, vals))
    i = min(max(int(i), 0), len(order))
    return np.bincount(run_ids[order[:i]], minlength=k).astype(np.int64)


def rank_sweep(total: int, n: int = 13) -> list[int]:
    """Deterministic output ranks covering [0, total] incl. both ends."""
    if total <= 0:
        return [0]
    pts = set(np.linspace(0, total, n, dtype=np.int64).tolist())
    pts.update([1, total - 1, total // 2])
    return sorted(p for p in pts if 0 <= p <= total)


def pairwise_cases():
    """List of ``(name, a, b)`` sorted 1-D arrays for Algorithm 1."""
    rng = np.random.default_rng(99)
    imax = np.iinfo(np.int32).max
    return [
        (
            "dup_heavy",
            np.sort(rng.integers(0, 4, 57)).astype(np.int32),
            np.sort(rng.integers(0, 4, 43)).astype(np.int32),
        ),
        (
            "pm_inf",
            np.sort(
                np.concatenate(
                    [[-np.inf, np.inf], rng.normal(size=30)]
                ).astype(np.float32)
            ),
            np.sort(
                np.concatenate(
                    [[-np.inf, -np.inf, np.inf], rng.normal(size=20)]
                ).astype(np.float32)
            ),
        ),
        (
            "dtype_max",
            np.sort(rng.integers(imax - 2, imax + 1, 17)).astype(np.int32),
            np.sort(rng.integers(imax - 2, imax + 1, 23)).astype(np.int32),
        ),
        (
            "pre_sorted",
            np.arange(0, 40, 2, dtype=np.int32),
            np.arange(40, 70, dtype=np.int32),
        ),
        (
            "empty_side",
            np.sort(rng.integers(0, 9, 12)).astype(np.int32),
            np.empty(0, np.int32),
        ),
    ]


def oracle_pairwise(a: np.ndarray, b: np.ndarray, i: int):
    """Two-finger stable co-rank oracle: unique (j, k), j + k = i."""
    j = k = 0
    while j + k < i:
        if j < len(a) and (k >= len(b) or a[j] <= b[k]):
            j += 1
        else:
            k += 1
    return j, k
