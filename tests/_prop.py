"""Property-testing shim: hypothesis when available, seeded fallback offline.

The container has no network, so ``hypothesis`` may be absent.  When it is
installed, this module re-exports the real ``given``/``settings``/``st`` and
the property tests run unchanged.  When it is missing, a tiny seeded-random
engine stands in: each ``@given`` test runs a fixed number of deterministic
examples drawn from lightweight re-implementations of the handful of
strategies the suite uses (``integers``, ``floats``, ``lists``,
``sampled_from``, ``data``).  No shrinking, no database — just enough to keep
collection green and the properties exercised offline.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # offline fallback
    import zlib

    import numpy as _np

    HAVE_HYPOTHESIS = False

    # Fallback examples per test: enough to exercise the property without
    # recompiling jitted functions hundreds of times in a Python loop.
    _FALLBACK_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    class _DataStrategy(_Strategy):
        """Marker for ``st.data()``; draws happen inside the test body."""

        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.sample(self._rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def floats(min_value, max_value, **_kwargs):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value))
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(size)]

            return _Strategy(sample)

        @staticmethod
        def sampled_from(choices):
            seq = list(choices)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def data():
            return _DataStrategy()

    st = _St()

    def settings(max_examples=None, deadline=None, **_kwargs):
        """No-op decorator (example count is fixed in the fallback)."""

        def deco(fn):
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            # NOTE: no functools.wraps — pytest must see a zero-arg
            # signature, not the wrapped one (else params look like
            # fixtures).
            def runner():
                for example in range(_FALLBACK_MAX_EXAMPLES):
                    # Deterministic per (test, example) so failures replay
                    # (crc32, not hash(): hash() is salted per process).
                    seed = zlib.crc32(f"{fn.__name__}:{example}".encode())
                    rng = _np.random.default_rng(seed)
                    drawn = [s.sample(rng) for s in strategies]
                    fn(*drawn)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return deco
