"""Dry-run regression guard: one real cell must lower+compile on the
production mesh (256 fake devices, subprocess so pytest keeps 1 device).

This is the fast canary for deliverable (e): if sharding specs, cache
layouts or the step functions regress, this fails in ~a minute instead of
at the full 80-cell sweep.
"""

import os
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess compile on 256 fake devices

REPO = pathlib.Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os, json, tempfile
os.environ["DRYRUN_DEVICES"] = "256"
import sys
from repro.launch.dryrun import run_cell

out = tempfile.mkdtemp()
rec = run_cell("qwen3-0.6b", "decode_32k", False, out, force=True)
assert rec["status"] == "ok", rec
assert rec["collectives"]["total_bytes"] > 0
assert rec["weighted"]["flops"] > 0
mem = rec["memory"]
assert mem["argument_size_in_bytes"] < 16 * 2**30  # fits one v5e HBM
print("DRYRUN CELL OK")
"""


def test_dryrun_decode_cell_compiles():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    p = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-3000:]}"
    assert "DRYRUN CELL OK" in p.stdout
