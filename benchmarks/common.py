"""Benchmark timing helpers (CPU wall-clock; roofline cells come from the
dry-run, not from these timings)."""

from __future__ import annotations

import time

import jax


class TimingStats(float):
    """Float (median µs/call) carrying the min/median/p90 spread.

    Arithmetic on the result keeps working for existing callers (ratios,
    speedups); ``row()`` picks the extra percentiles up automatically.
    """

    min_us: float
    p50_us: float
    p90_us: float

    def __new__(cls, samples_us):
        s = sorted(samples_us)
        n = len(s)
        self = super().__new__(cls, s[n // 2])
        self.min_us = s[0]
        self.p50_us = s[n // 2]
        self.p90_us = s[min(n - 1, (9 * n) // 10)]
        return self


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> TimingStats:
    """Per-call microseconds (after jit warmup).

    Returns a ``TimingStats``: behaves as the median float (back-compat —
    callers do arithmetic with it) but also reports ``min_us`` and
    ``p90_us`` so a noisy-neighbour spike is visible instead of silently
    folded into a single median number.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return TimingStats([t * 1e6 for t in times])


def row(name: str, us: float, derived: str = "") -> str:
    if isinstance(us, TimingStats):
        spread = f"min={us.min_us:.1f};p90={us.p90_us:.1f}"
        derived = f"{derived};{spread}" if derived else spread
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
