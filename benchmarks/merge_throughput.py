"""Benchmark C4 — merge throughput: the paper's merge vs baselines.

Compared on equal terms (jitted, 1-D arrays):
  * rank-merge (ours, data-parallel form)
  * partitioned two-finger merge (ours, Algorithm 2 with vmapped PEs)
  * Pallas kernel in interpret mode (correctness path; TPU is the target)
  * classic equidistant-splitter merge (the factor-2 baseline)
  * lexicographic stable merge (the stability-workaround baseline)
  * XLA's native sort of the concatenation (the "don't exploit
    sortedness" baseline)
Derived column: million elements merged per second.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import (
    merge_by_ranking,
    merge_equidistant,
    merge_lexicographic,
    merge_partitioned,
)


def main():
    rng = np.random.default_rng(2)
    for size in (1 << 16, 1 << 20):
        a = jnp.asarray(np.sort(rng.integers(0, 1 << 30, size)), jnp.int32)
        b = jnp.asarray(np.sort(rng.integers(0, 1 << 30, size)), jnp.int32)
        total = 2 * size

        def meps(us):
            return f"{total / us:.1f}Melem/s"

        us = time_fn(merge_by_ranking, a, b)
        row(f"merge/rank/{total}", us, meps(us))
        us = time_fn(lambda x, y: merge_partitioned(x, y, p=64), a, b)
        row(f"merge/partitioned_p64/{total}", us, meps(us))
        us = time_fn(lambda x, y: merge_equidistant(x, y, p=64), a, b)
        row(f"merge/equidistant_p64/{total}", us, meps(us))
        us = time_fn(merge_lexicographic, a, b)
        row(f"merge/lexicographic/{total}", us, meps(us))
        us = time_fn(
            jnp.sort, jnp.concatenate([a, b])
        )
        row(f"merge/xla_sort_concat/{total}", us, meps(us))

    # Pallas interpret mode is Python-speed; report once, small size.
    from repro.kernels.merge import merge_pallas

    size = 1 << 12
    a = jnp.asarray(np.sort(rng.integers(0, 1 << 30, size)), jnp.int32)
    b = jnp.asarray(np.sort(rng.integers(0, 1 << 30, size)), jnp.int32)
    us = time_fn(lambda x, y: merge_pallas(x, y, tile=512), a, b)
    row(f"merge/pallas_interpret/{2 * size}", us, f"{2 * size / us:.2f}Melem/s")


if __name__ == "__main__":
    main()
