"""Profile one dry-run cell: top HBM-traffic and collective lines.

  PYTHONPATH=src python benchmarks/profile_cell.py musicgen-medium train_4k \
      [--multi-pod] [--override k=v ...]
"""

import os

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count="
    f"{os.environ.get('DRYRUN_DEVICES', '512')} "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import ast
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--override", action="append", default=[])
    ap.add_argument("--top", type=int, default=14)
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    from repro.launch.dryrun import build_cell
    from repro.launch.hlo_stats import collective_bytes, hlo_flops_bytes, top_traffic

    mesh, cfg, fn, cell_args = build_cell(
        args.arch, args.shape, args.multi_pod, overrides or None
    )
    with mesh:
        hlo = fn.lower(*cell_args).compile().as_text()
    w = hlo_flops_bytes(hlo)
    c = collective_bytes(hlo)
    print(f"flops/dev {w['flops']:.3e} ({w['flops'] / 197e12:.3f}s)  "
          f"mem {w['bytes'] / 819e9:.3f}s  coll {c['total_bytes'] / 50e9:.3f}s")
    print(f"collectives: {c['per_op_bytes']}")
    print("--- top traffic ---")
    for gib, tag in top_traffic(hlo, args.top):
        print(f"{gib:9.2f} GiB  {tag[:120]}")


if __name__ == "__main__":
    main()
