"""Benchmark harness entry point: ``python -m benchmarks.run``.

One section per paper claim/table (DESIGN.md §1, §9) plus the framework
benchmarks and the roofline report.  Prints ``name,us_per_call,derived``
CSV rows and writes the machine-readable baselines ``BENCH_moe.json``
(capacity vs dropless dispatch trajectory) and ``BENCH_kway.json``
(fan-out / k-way merge throughput) for later PRs to beat.
"""

from __future__ import annotations

import pathlib
import sys

# Baselines live at the repo root regardless of the invoking cwd — a run
# from a scratch directory must not scatter BENCH_*.json copies there.
_REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

MOE_JSON = str(_REPO_ROOT / "BENCH_moe.json")
KWAY_JSON = str(_REPO_ROOT / "BENCH_kway.json")
EXTERNAL_JSON = str(_REPO_ROOT / "BENCH_external.json")
SERVE_JSON = str(_REPO_ROOT / "BENCH_serve.json")


def main() -> None:
    from benchmarks import (
        corank_bound,
        external_sort,
        kway_throughput,
        load_balance,
        merge_throughput,
        moe_dispatch,
        roofline,
        serve_decode,
        stability_cost,
    )

    print("name,us_per_call,derived")
    sections = [
        ("C1: co-rank iteration bound (Prop 1)", corank_bound.main),
        ("C2: load balance vs classic partition (Prop 2)", load_balance.main),
        ("C3: stability at zero cost", stability_cost.main),
        ("C4: merge throughput vs baselines", merge_throughput.main),
        ("C7: k-way fan-out throughput",
         lambda: kway_throughput.main(KWAY_JSON)),
        ("E1: out-of-core external sort",
         lambda: external_sort.main(EXTERNAL_JSON)),
        ("F1: MoE dispatch (framework integration)",
         lambda: moe_dispatch.main(MOE_JSON)),
        ("S1: serving decode step (continuous batching)",
         lambda: serve_decode.main(SERVE_JSON)),
        ("G: roofline from dry-run artifacts", roofline.main),
    ]
    failures = 0
    for title, fn in sections:
        print(f"# --- {title} ---", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness running, report at end
            failures += 1
            print(f"# SECTION FAILED: {title}: {type(e).__name__}: {e}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
