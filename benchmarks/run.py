"""Benchmark harness entry point: ``python -m benchmarks.run``.

One section per paper claim/table (DESIGN.md §1, §9) plus the framework
benchmarks and the roofline report.  Prints ``name,us_per_call,derived``
CSV rows.
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        corank_bound,
        load_balance,
        merge_throughput,
        moe_dispatch,
        roofline,
        stability_cost,
    )

    print("name,us_per_call,derived")
    sections = [
        ("C1: co-rank iteration bound (Prop 1)", corank_bound.main),
        ("C2: load balance vs classic partition (Prop 2)", load_balance.main),
        ("C3: stability at zero cost", stability_cost.main),
        ("C4: merge throughput vs baselines", merge_throughput.main),
        ("F1: MoE dispatch (framework integration)", moe_dispatch.main),
        ("G: roofline from dry-run artifacts", roofline.main),
    ]
    failures = 0
    for title, fn in sections:
        print(f"# --- {title} ---", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness running, report at end
            failures += 1
            print(f"# SECTION FAILED: {title}: {type(e).__name__}: {e}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
