"""Benchmark C3 — stability at zero cost.

The paper's claim: co-rank stability needs no key widening.  We measure
the cost of the standard workaround (lexicographic (key, index) sort) vs
our merge on the same inputs, and report the extra bytes the workaround
materialises (an index array of the full output length).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import merge_by_ranking, merge_lexicographic


def main():
    rng = np.random.default_rng(3)
    for size in (1 << 18,):
        # heavy duplicates — stability actually matters here
        a = jnp.asarray(np.sort(rng.integers(0, 64, size)), jnp.int32)
        b = jnp.asarray(np.sort(rng.integers(0, 64, size)), jnp.int32)
        total = 2 * size
        us_ours = time_fn(merge_by_ranking, a, b)
        us_lex = time_fn(merge_lexicographic, a, b)
        extra_bytes = total * 4  # the int32 tie-break key
        row(
            f"stability/corank_merge/{total}",
            us_ours,
            "extra_bytes=0",
        )
        row(
            f"stability/lexicographic/{total}",
            us_lex,
            f"extra_bytes={extra_bytes};slowdown={us_lex / us_ours:.2f}x",
        )


if __name__ == "__main__":
    main()
