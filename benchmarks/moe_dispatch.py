"""Framework benchmark — MoE token dispatch: stable merge sort vs
alternatives, plus determinism and drop-fairness checks.

This is the paper *inside* the framework: the dispatch plan is a stable
sort of (token, expert) assignments; we compare against (a) XLA's native
stable argsort and (b) the lexicographic 64-bit key workaround that
unstable sorts force.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core.mergesort import sort_key_val
from repro.models.moe import moe_dispatch


def main():
    rng = np.random.default_rng(4)
    t, k, e = 16384, 4, 16  # dbrx-like tile of tokens
    experts = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    flat = experts.reshape(-1)
    idx = jnp.arange(t * k, dtype=jnp.int32)

    us = time_fn(
        jax.jit(lambda f, i: sort_key_val(f, i)[1]), flat, idx
    )
    row(f"moe_dispatch/merge_sort/T{t}k{k}", us, "stable=True;key_bytes=4")

    us2 = time_fn(
        jax.jit(lambda f: jnp.argsort(f, stable=True)), flat
    )
    row(f"moe_dispatch/xla_stable_argsort/T{t}k{k}", us2, "stable=True;key_bytes=4")

    # lexicographic 64-bit workaround (what unstable sorts force)
    us3 = time_fn(
        jax.jit(
            lambda f, i: jnp.argsort(
                f.astype(jnp.int64) * (t * k) + i.astype(jnp.int64)
            )
        ),
        flat,
        idx,
    )
    row(f"moe_dispatch/lexicographic64/T{t}k{k}", us3, "stable=via-widening;key_bytes=8")

    # semantic checks: determinism + fair (positional) capacity drops
    cap = t * k // e // 2  # force drops
    s1 = moe_dispatch(experts, e, cap, use_merge_sort=True)
    s2 = moe_dispatch(experts, e, cap, use_merge_sort=True)
    same = all(
        bool(jnp.array_equal(x, y)) for x, y in zip(s1, s2)
    )
    sorted_e, slot_token, _, slot_pos, keep = s1
    # within every expert, kept tokens are exactly the earliest ones
    fair = True
    se, st_, sp, kp = map(np.asarray, (sorted_e, slot_token, slot_pos, keep))
    for ex in range(e):
        seg = st_[se == ex]
        kept = kp[se == ex]
        if kept.any() and (~kept).any():
            fair &= seg[kept].max() < seg[~kept].min() or bool(
                (np.sort(seg[kept]) == seg[kept]).all()
            )
    row(
        f"moe_dispatch/semantics/T{t}k{k}",
        0.0,
        f"deterministic={same};drops_positional={bool(fair)}",
    )


if __name__ == "__main__":
    main()
