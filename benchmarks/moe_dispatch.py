"""Framework benchmark — MoE token dispatch: stable merge sort vs
alternatives, determinism/drop-fairness checks, and the capacity vs
dropless trajectory (time, drop rate, per-device payload) across routing
skews.

This is the paper *inside* the framework: the dispatch plan is a stable
sort of (token, expert) assignments; we compare against (a) XLA's native
stable argsort and (b) the lexicographic 64-bit key workaround that
unstable sorts force.  The capacity-vs-dropless sweep is the first perf
trajectory for the dropless refactor — ``main(json_path=...)`` writes
the machine-readable baseline later PRs have to beat.
"""

from __future__ import annotations

import json

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core.mergesort import sort_key_val
from repro.models.moe import (
    _dispatch_combine_one_group,
    _dropless_moe,
    moe_dispatch,
)

# EP mesh size the payload model assumes (contiguous expert ownership,
# ceil(E/p) experts per device) — matches the 8-device subprocess tests.
EP_DEVICES = 8


def _routing(pattern: str, rng, t: int, k: int, e: int) -> np.ndarray:
    """(t, k) expert choices for one skew pattern."""
    if pattern == "uniform":
        return rng.integers(0, e, (t, k))
    if pattern == "skewed":
        # zipf-ish popularity: expert e with weight 1/(e+1)
        probs = 1.0 / np.arange(1, e + 1)
        probs /= probs.sum()
        return rng.choice(e, size=(t, k), p=probs)
    if pattern == "one_hot":
        return np.zeros((t, k), np.int64)  # adversarial: everything -> 0
    raise ValueError(pattern)


def _payload_rows(counts: np.ndarray, capacity: int | None, e: int) -> int:
    """Max rows any EP device receives: its full slot block under
    capacity dispatch (shipped regardless of fill), or the sum of its
    owned experts' real segment sizes under dropless / exact cuts."""
    e_per = -(-e // EP_DEVICES)
    if capacity is not None:
        return capacity * e_per
    return max(
        int(counts[dev * e_per : (dev + 1) * e_per].sum())
        for dev in range(EP_DEVICES)
    )


def _sort_comparison(rng, t: int, k: int, e: int) -> None:
    experts = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    flat = experts.reshape(-1)
    idx = jnp.arange(t * k, dtype=jnp.int32)

    us = time_fn(jax.jit(lambda f, i: sort_key_val(f, i)[1]), flat, idx)
    row(f"moe_dispatch/merge_sort/T{t}k{k}", us, "stable=True;key_bytes=4")

    us2 = time_fn(jax.jit(lambda f: jnp.argsort(f, stable=True)), flat)
    row(f"moe_dispatch/xla_stable_argsort/T{t}k{k}", us2,
        "stable=True;key_bytes=4")

    # lexicographic 64-bit workaround (what unstable sorts force)
    us3 = time_fn(
        jax.jit(
            lambda f, i: jnp.argsort(
                f.astype(jnp.int64) * (t * k) + i.astype(jnp.int64)
            )
        ),
        flat,
        idx,
    )
    row(f"moe_dispatch/lexicographic64/T{t}k{k}", us3,
        "stable=via-widening;key_bytes=8")

    # semantic checks: determinism + fair (positional) capacity drops
    cap = t * k // e // 2  # force drops
    s1 = moe_dispatch(experts, e, cap, use_merge_sort=True)
    s2 = moe_dispatch(experts, e, cap, use_merge_sort=True)
    same = all(bool(jnp.array_equal(x, y)) for x, y in zip(s1, s2))
    assert same, "moe_dispatch is nondeterministic across two calls"
    sorted_e, slot_token, _, slot_pos, keep = s1
    se, st_, kp = map(np.asarray, (sorted_e, slot_token, keep))
    for ex in range(e):
        seg, kept = st_[se == ex], kp[se == ex]
        if kept.any() and (~kept).any():
            # strict earliest-kept: every kept token must precede every
            # dropped token of the same expert — positional fairness.
            assert seg[kept].max() < seg[~kept].min(), (
                f"unfair capacity drop for expert {ex}: kept token "
                f"{seg[kept].max()} after dropped token {seg[~kept].min()}"
            )
    row(f"moe_dispatch/semantics/T{t}k{k}", 0.0,
        "deterministic=True;drops_positional=True")


def main(json_path: str | None = None):
    rng = np.random.default_rng(4)
    t, k, e = 16384, 4, 16  # dbrx-like tile of tokens
    _sort_comparison(rng, t, k, e)

    # --- capacity vs dropless trajectory across routing skews -----------
    d, ff = 256, 512
    tb = 2048  # smaller token tile so the dense layer timing stays short
    cap_factor = 1.25
    capacity = max(int(np.ceil(tb * k / e * cap_factor)), k)
    params = {
        "w_gate": jnp.asarray(rng.standard_normal((e, d, ff)), jnp.float32),
        "w_up": jnp.asarray(rng.standard_normal((e, d, ff)), jnp.float32),
        "w_down": jnp.asarray(rng.standard_normal((e, ff, d)), jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((1, tb, d)), jnp.float32)

    results: dict = {
        "config": {"tokens": tb, "top_k": k, "n_experts": e, "d_model": d,
                   "moe_ff": ff, "capacity_factor": cap_factor,
                   "capacity": capacity, "ep_devices": EP_DEVICES},
        "patterns": {},
    }
    xt = x.reshape(tb, d)
    w_uniform = jnp.full((tb, k), 1.0 / k, jnp.float32)

    def cap_ffn(px, wx, ex):
        ex_in, combine = _dispatch_combine_one_group(
            px, wx, ex, e, k, capacity, True
        )
        gate = jnp.einsum("ecd,edf->ecf", ex_in, params["w_gate"])
        up = jnp.einsum("ecd,edf->ecf", ex_in, params["w_up"])
        h = jax.nn.silu(gate) * up
        return combine(jnp.einsum("ecf,efd->ecd", h, params["w_down"]))

    def drop_ffn(px, wx, ex):
        return _dropless_moe(params, px, wx, ex, e, k, True)

    for pattern in ("uniform", "skewed", "one_hot"):
        experts = _routing(pattern, rng, tb, k, e)
        ex = jnp.asarray(experts, jnp.int32)

        # time the dispatch + expert-FFN + combine core on the real
        # routing pattern (routing itself is identical work in both paths
        # and is excluded so the trajectory isolates dispatch cost).
        us_cap = time_fn(jax.jit(cap_ffn), xt, w_uniform, ex)
        us_drop = time_fn(jax.jit(drop_ffn), xt, w_uniform, ex)

        counts = np.bincount(experts.reshape(-1), minlength=e)
        dropped = int(np.maximum(counts - capacity, 0).sum())
        drop_rate = dropped / (tb * k)
        pay_cap = _payload_rows(counts, capacity, e)
        pay_drop = _payload_rows(counts, None, e)

        results["patterns"][pattern] = {
            "capacity": {"layer_us": us_cap, "drop_rate": drop_rate,
                         "max_device_payload_rows": pay_cap},
            "dropless": {"layer_us": us_drop, "drop_rate": 0.0,
                         "max_device_payload_rows": pay_drop},
        }
        row(f"moe_dispatch/capacity/{pattern}/T{tb}k{k}", us_cap,
            f"drop_rate={drop_rate:.4f};payload_rows={pay_cap}")
        row(f"moe_dispatch/dropless/{pattern}/T{tb}k{k}", us_drop,
            f"drop_rate=0.0000;payload_rows={pay_drop}")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", flush=True)
    return results


if __name__ == "__main__":
    main("BENCH_moe.json")
