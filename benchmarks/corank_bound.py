"""Benchmark C1 — Proposition 1: co-rank iterations vs the log bound.

Reports measured max/mean iterations against ``ceil(log2 min(m,n,i,m+n-i))``
across sizes and input distributions, plus the time per co-rank call.
"""

from __future__ import annotations

import math

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import co_rank_batch


def _dataset(kind, m, n, rng):
    if kind == "uniform":
        a = np.sort(rng.integers(0, 1 << 30, m))
        b = np.sort(rng.integers(0, 1 << 30, n))
    elif kind == "disjoint":  # all of A < all of B (adversarial)
        a = np.sort(rng.integers(0, 1 << 20, m))
        b = np.sort(rng.integers(1 << 20, 1 << 21, n))
    else:  # heavy duplicates
        a = np.sort(rng.integers(0, 8, m))
        b = np.sort(rng.integers(0, 8, n))
    return jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32)


def main():
    rng = np.random.default_rng(0)
    for m, n in [(1 << 14, 1 << 14), (1 << 18, 1 << 10), (1 << 20, 1 << 20)]:
        for kind in ("uniform", "disjoint", "dups"):
            a, b = _dataset(kind, m, n, rng)
            ranks = jnp.asarray(
                rng.integers(0, m + n + 1, 512), jnp.int32
            )
            res = co_rank_batch(ranks, a, b)
            iters = np.asarray(res.iterations)
            bounds = np.asarray(
                [
                    max(
                        1,
                        math.ceil(
                            math.log2(
                                max(
                                    1,
                                    min(m, n, max(int(i), 1), max(m + n - int(i), 1)),
                                )
                            )
                        ),
                    )
                    for i in np.asarray(ranks)
                ]
            )
            assert (iters <= bounds + 1).all(), "Prop 1 bound violated"
            us = time_fn(
                lambda r: co_rank_batch(r, a, b).j, ranks
            ) / len(ranks)
            row(
                f"corank/{kind}/m{m}_n{n}",
                us,
                f"max_iters={iters.max()};bound={bounds.max()};"
                f"mean_iters={iters.mean():.1f}",
            )


if __name__ == "__main__":
    main()
