"""Benchmark E1 — out-of-core external sort vs in-memory sort.

Sustained elements/sec of ``repro.external.external_sort`` (spill +
co-rank-streamed k-way merge, end-to-end including host I/O and
planning) across inputs of 1–8x the configured device chunk, against
the in-memory ``sort_key_val`` at the same sizes.  On this CPU harness
"device memory" is simulated by the chunk size; the shape of the result
— external throughput flat in input size while staying within a small
constant of the in-memory sort — is the property later hardware PRs
must preserve.

Derived columns: million elements sorted per second and the slowdown
vs the in-memory sort of the same input (``vs_inmem``; the acceptance
bound is 3x at the largest in-memory-comparable size).
"""

from __future__ import annotations

import json
import shutil
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import TimingStats, row
from repro.core.mergesort import sort_key_val
from repro.external.api import external_sort

CHUNK = 1 << 15
FANOUT = 8
WINDOW = CHUNK // FANOUT


def _time_external(keys, vals, *, iters: int = 3) -> TimingStats:
    """End-to-end wall time per call; every iteration re-sorts from
    scratch in a fresh workdir (resume would otherwise short-circuit)."""
    samples = []
    for _ in range(iters):
        workdir = tempfile.mkdtemp(prefix="repro-bench-external-")
        try:
            t0 = time.perf_counter()
            sk, _sv = external_sort(
                keys, vals, chunk=CHUNK, fanout=FANOUT, window=WINDOW,
                workdir=workdir,
            )
            _ = sk[-1] if len(sk) else None  # touch the mmap tail
            samples.append((time.perf_counter() - t0) * 1e6)
        finally:
            shutil.rmtree(workdir, ignore_errors=True)
    return TimingStats(samples)


def main(json_path: str | None = None):
    rng = np.random.default_rng(11)
    records: list[dict] = []

    inmem = jax.jit(sort_key_val)
    for mult in (1, 2, 4, 8):
        n = mult * CHUNK
        keys = rng.integers(0, 1 << 30, n).astype(np.int32)
        vals = np.arange(n, dtype=np.int32)

        kd, vd = jnp.asarray(keys), jnp.asarray(vals)
        jax.block_until_ready(inmem(kd, vd))  # warmup / compile
        samples = []
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(inmem(kd, vd))
            samples.append((time.perf_counter() - t0) * 1e6)
        us_mem = TimingStats(samples)
        row(
            f"external_sort/inmem/{n}", us_mem, f"{n / us_mem:.2f}Melem/s"
        )
        records.append({
            "name": f"external_sort/inmem/{n}", "us_per_call": us_mem,
            "melem_per_s": n / us_mem, "size": n,
        })

        us_ext = _time_external(keys, vals)
        ratio = us_ext / us_mem
        row(
            f"external_sort/external/x{mult}/{n}", us_ext,
            f"{n / us_ext:.2f}Melem/s;vs_inmem={ratio:.2f}x",
        )
        records.append({
            "name": f"external_sort/external/x{mult}/{n}",
            "us_per_call": us_ext, "melem_per_s": n / us_ext,
            "size": n, "chunk": CHUNK, "fanout": FANOUT, "window": WINDOW,
            "vs_inmem": ratio,
        })

    if json_path:
        with open(json_path, "w") as f:
            json.dump({"records": records}, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", flush=True)
    return records


if __name__ == "__main__":
    main("BENCH_external.json")
