"""Benchmark S1 — continuous-batching decode-step latency.

Two sweeps on the qwen3-0.6b smoke config:

* ``serve_topk/b{batch}/fanout{f}`` — the batched merge-based top-k
  (``sample_topk_batched``'s cut) over a serving-scale vocab, batch
  {1, 2, 4, 8} x fanout {2, 4, 16}.  The tournament performs one
  ``merge_kway_ranked`` cut per round for the *whole batch*: the round
  count is a function of vocab/fanout geometry only (``rounds=`` in the
  derived column — identical down each batch column), so the dispatch/
  fusion count per step is flat in batch size and the extra rows ride
  inside already-launched ops.  The ``vs_b1`` ratio shows how much of
  that the timing realises: on parallel hardware (and whenever per-call
  overhead matters) it is < batch; on a single-core CPU device the cut
  is bandwidth-bound and ``vs_b1`` ~ batch is the expected reading.
* ``serve_step/b{batch}`` — one full engine step (ragged decode +
  batched sample + host scheduling) with every slot active: the latency
  a request actually observes per token, and the headline sub-linear
  record — batching decode amortises the model step, so ``vs_b1`` stays
  well under ``batch`` (tok/s grows with the pool) even on CPU.

Each record also carries the ``serve.topk_merge_rounds`` /
``serve.topk_candidates`` counters captured from ``repro.obs`` during
the timed call — the machine-checkable evidence that the merge-cut
count did not grow with the batch.

``--guard [baseline.json]`` re-times only the ``serve_topk/*`` records
and exits 1 on a >10% regression against the checked-in
``BENCH_serve.json`` (min-over-iterations statistic, one 4x-iteration
retry — same policy as ``kway_throughput --guard``); the no-regression
lane of ``scripts/verify.sh --serve``.
"""

from __future__ import annotations

import json
import sys

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro import obs
from repro.serving.sampling import batched_topk

VOCAB = 1 << 17  # serving-scale vocab (qwen3 family is ~152k)
TOPK = 50
BATCHES = (1, 2, 4, 8)
FANOUTS = (2, 4, 16)


def _logits(rng, b):
    return jnp.asarray(rng.standard_normal((b, VOCAB)), jnp.float32)


def _tournament_counters(b: int, fanout: int) -> dict:
    """Capture the serve.topk_* records one batched call emits."""
    with obs.capture() as records:
        rng = np.random.default_rng(0)
        jax.block_until_ready(
            batched_topk(_logits(rng, b), TOPK, fanout=fanout)
        )
    out = {}
    for r in records:
        if r["metric"] == "serve.topk_merge_rounds":
            out["merge_rounds"] = r["value"]
        elif r["metric"] == "serve.topk_candidates":
            out["final_cut_candidates"] = r["value"]
    return out


def _topk_timers() -> dict:
    """``{record name: () -> TimingStats}`` for the guarded subset."""
    rng = np.random.default_rng(11)
    timers = {}
    for fanout in FANOUTS:
        for b in BATCHES:
            x = _logits(rng, b)
            timers[f"serve_topk/b{b}/fanout{fanout}"] = (
                lambda x=x, f=fanout, **kw: time_fn(
                    lambda v: batched_topk(v, TOPK, fanout=f), x, **kw
                )
            )
    return timers


def _engine_steps(records, rec):
    """Steady-state full-step latency with every slot active."""
    from repro.configs.registry import ARCHS, smoke_config
    from repro.models.transformer import init_params
    from repro.serving import DecodeEngine, Request

    cfg = smoke_config(ARCHS["qwen3-0.6b"])
    params, _ = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(3)
    base_us = None
    for b in BATCHES:
        eng = DecodeEngine(cfg, params, max_len=96, max_batch=b,
                           queue_depth=2 * b, sampler="topk",
                           top_k=min(TOPK, cfg.vocab), seed=1)
        for rid in range(b):
            eng.submit(Request(rid, rng.integers(1, cfg.vocab, 4,
                                                 dtype=np.int32), 80))
        eng.step()  # admit everyone; subsequent steps are steady-state
        us = time_fn(eng.step)
        tag = f"{b / (us / 1e6):.0f}tok/s"
        if b == BATCHES[0]:
            base_us = us
        else:
            tag += f";vs_b1={us / base_us:.2f}x"
        row(f"serve_step/b{b}", us, tag)
        rec(f"serve_step/b{b}", us, batch=b,
            tok_per_s=b / (us / 1e6), vs_b1=us / base_us)


def main(json_path: str | None = None):
    records: list[dict] = []

    def rec(name: str, us: float, **extra):
        records.append({"name": name, "us_per_call": us, **extra})

    base_by_fanout: dict[int, float] = {}
    for name, timer in _topk_timers().items():
        _, btag, ftag = name.split("/")
        b, fanout = int(btag[1:]), int(ftag[6:])
        # the serve.topk_* counters are recorded at trace time, so the
        # obs-enabled capture must run before the jit cache is warm
        counters = _tournament_counters(b, fanout)
        us = timer()
        tag = f"{b * VOCAB / us:.1f}Melem/s"
        if b == 1:
            base_by_fanout[fanout] = us
            vs_b1 = 1.0
        else:
            vs_b1 = us / base_by_fanout[fanout]
            tag += f";vs_b1={vs_b1:.2f}x"
            sub = "sublinear" if vs_b1 < b else "LINEAR-OR-WORSE"
            tag += f";{sub}"
        if "merge_rounds" in counters:
            tag += f";rounds={counters['merge_rounds']}"
        row(name, us, tag)
        rec(name, us, batch=b, fanout=fanout, vs_b1=vs_b1,
            melem_per_s=b * VOCAB / us, **counters)

    _engine_steps(records, rec)

    if json_path:
        with open(json_path, "w") as f:
            json.dump({"records": records}, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", flush=True)
    return records


def guard(baseline_path: str = "BENCH_serve.json", tol: float = 0.10) -> int:
    """Fail (return 1) if any ``serve_topk`` record regresses > ``tol``
    against the checked-in baseline.  Same policy as
    ``kway_throughput.guard``: min-over-iterations statistic, one 4x
    retry before a record counts as regressed, new records pass."""
    with open(baseline_path) as f:
        baseline = {
            r["name"]: r["us_per_call"] for r in json.load(f)["records"]
        }
    failed = 0
    for name, timer in _topk_timers().items():
        base = baseline.get(name)
        if base is None:
            row(name, timer(), "no baseline — skipped")
            continue
        stats = timer()
        if stats.min_us / base > 1.0 + tol:
            stats = timer(iters=20)
        us = stats.min_us
        ratio = us / base
        ok = ratio <= 1.0 + tol
        row(name, us, f"baseline={base:.0f}us;x{ratio:.2f};"
            + ("ok" if ok else f"REGRESSION>{tol:.0%}"))
        failed += not ok
    if failed:
        print(f"# bench guard: {failed} record(s) regressed "
              f"beyond {tol:.0%}", flush=True)
    else:
        print("# bench guard: all serve_topk timings within "
              f"{tol:.0%} of baseline", flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    if "--guard" in sys.argv[1:]:
        rest = [a for a in sys.argv[1:] if a != "--guard"]
        sys.exit(guard(rest[0] if rest else "BENCH_serve.json"))
    main("BENCH_serve.json")
