"""Benchmark C2 — Proposition 2: perfect balance vs the classic partition.

For each input distribution: max/mean segment size of (a) the paper's
co-rank partition (always ceil/floor), (b) the classic equidistant-splitter
partition (up to 2x).  The 'derived' column is the load-imbalance factor
max/ideal — on TPU this is exactly the tile-padding waste factor
(DESIGN.md §3).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import (
    co_rank_batch,
    partition_bounds,
    partition_sizes_equidistant,
)


def main():
    rng = np.random.default_rng(1)
    m = n = 1 << 20
    p = 64
    cases = {
        "uniform": (
            np.sort(rng.integers(0, 1 << 30, m)),
            np.sort(rng.integers(0, 1 << 30, n)),
        ),
        "disjoint": (
            np.arange(m, dtype=np.int32),
            np.arange(m, 2 * m, dtype=np.int32),
        ),
        "interleaved_runs": (
            np.sort(np.repeat(np.arange(m // 64), 64)),
            np.sort(np.repeat(np.arange(n // 64) * 2, 64)),
        ),
    }
    for kind, (a, b) in cases.items():
        a = jnp.asarray(a, jnp.int32)
        b = jnp.asarray(b, jnp.int32)
        # paper partition: exact output blocks
        bounds = partition_bounds(m + n, p)
        sizes_ours = np.diff(np.asarray(bounds))
        # classic partition (2p segments for p PEs)
        sizes_base = np.asarray(partition_sizes_equidistant(a, b, p))
        ideal_ours = (m + n) / p
        ideal_base = (m + n) / (2 * p)
        us = time_fn(lambda: co_rank_batch(bounds, a, b).j)
        row(
            f"load_balance/corank/{kind}",
            us,
            f"max={sizes_ours.max()};imbalance={sizes_ours.max() / ideal_ours:.4f}",
        )
        us_b = time_fn(lambda: partition_sizes_equidistant(a, b, p))
        row(
            f"load_balance/equidistant/{kind}",
            us_b,
            f"max={sizes_base.max()};imbalance={sizes_base.max() / ideal_base:.4f}",
        )


if __name__ == "__main__":
    main()
