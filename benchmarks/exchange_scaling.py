"""Exchange vs allgather scaling on 8 simulated devices.

    PYTHONPATH=src python benchmarks/exchange_scaling.py

For each problem size the two ``sharded_sort`` strategies are timed and
their compiled HLO is audited for per-device data movement:

* ``allgather`` replicates every run: each device *receives*
  ``(p-1) * N/p ~ N`` real elements and holds the full ``(p, N/p)``
  gathered array — per-device memory O(N), independent of p.
* ``exchange`` ships only the exact output block: each device receives
  ``N/p`` real elements (perfect balance by construction) plus
  ``O(p^2 log(N/p))`` int32 splitter metadata — per-device real payload
  O(N/p).  The static slot buffer is ``(p, capacity)``; its sentinel
  padding is wire overhead only for peers with skewed segments.

Reported columns: median us/call, then
``gathered_elems_per_dev / payload_elems_per_dev / max_allgather_elems``
derived from the HLO (the last column shows the exchange path never
all-gathers anything value-sized).
"""

import os
import pathlib
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)
# runnable both as `python benchmarks/exchange_scaling.py` and `-m`
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from benchmarks.common import row, time_fn
from repro.core.compat import shard_map
from repro.distributed import sharded_sort
from repro.launch.hlo_stats import collective_op_sizes


def _max_allgather_elems(txt: str) -> int:
    """Largest all-gather op output (ops only, not consumers of one)."""
    sizes = collective_op_sizes(txt, "all-gather")
    return max((el for _, el in sizes), default=0)


def main():
    devs = jax.devices()
    p = len(devs)
    mesh = Mesh(np.array(devs), ("x",))
    rng = np.random.default_rng(0)

    for log_n in (14, 16, 18, 20):
        n = 1 << log_n
        x = jnp.asarray(rng.integers(-(1 << 30), 1 << 30, n), jnp.int32)
        want = np.sort(np.asarray(x), kind="stable")
        for strategy in ("allgather", "exchange"):
            fn = jax.jit(
                shard_map(
                    lambda s, st=strategy: sharded_sort(s, "x", strategy=st),
                    mesh=mesh,
                    in_specs=(P("x"),),
                    out_specs=P("x"),
                )
            )
            # compile once: the executable serves the timing loop AND the
            # HLO audit (lower().compile() twice would double the SPMD
            # compile cost, the dominant term at the largest sizes)
            compiled = fn.lower(x).compile()
            got = np.asarray(compiled(x))
            np.testing.assert_array_equal(got, want)
            us = time_fn(compiled, x)
            max_ag = _max_allgather_elems(compiled.as_text())
            if strategy == "allgather":
                gathered = (p - 1) * (n // p)
                payload = (p - 1) * (n // p)
            else:
                gathered = 0
                payload = n // p
                assert max_ag < n, (
                    f"exchange path all-gathered {max_ag} >= N={n} elements"
                )
            row(
                f"sharded_sort_{strategy}_n{n}_p{p}",
                us,
                f"gathered/dev={gathered} payload/dev={payload} "
                f"max_allgather={max_ag}",
            )


if __name__ == "__main__":
    main()
