"""Roofline analysis from the dry-run artifacts (deliverable g).

For every (arch x shape x mesh) JSON produced by ``repro.launch.dryrun``:

  compute    = FLOPs_per_chip / 197e12            (TPU v5e bf16 peak)
  memory     = HBM_bytes_per_chip / 819e9
  collective = collective_bytes_per_chip / 50e9   (per-direction ICI link)

FLOPs/bytes are the *trip-count-weighted* walk of the partitioned HLO
(``hlo_stats.hlo_flops_bytes``) — XLA's cost_analysis counts while bodies
once, which would undercount a 61-layer scan 61x.  MODEL_FLOPS = 6·N·D
(dense) or 6·N_active·D (MoE) for train; 2·N(_active)·D for inference.

Outputs a markdown table (stdout + results/roofline.md) and the CSV rows
required by the bench harness.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link (per direction)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops(rec) -> float:
    """Analytic 6ND / 2ND for this cell (global, all chips)."""
    n_active = rec["active_params"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n_active * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * rec["global_batch"]


def chips(rec) -> int:
    return 512 if rec["mesh"] == "pod2x16x16" else 256


def useful_bytes(rec) -> float:
    """Minimal per-step HBM traffic (global): every active parameter read
    once (+written with moments for train), plus the KV/SSM cache read
    once for decode.  The memory-roofline 'useful work' analogue of 6ND."""
    n_active = rec["active_params"]
    pbytes = 2.0  # bf16 weights on the fast path
    if rec["kind"] == "train":
        # fwd read + bwd read + grad write + adam m/v read+write (4B each)
        return n_active * (2 * pbytes + 2 + 4 * 4)
    if rec["kind"] == "prefill":
        return n_active * pbytes  # params once; activations stream on-chip
    # decode: params + cache
    b, s = rec["global_batch"], rec["seq_len"]
    cache = rec.get("memory", {}).get("argument_size_in_bytes", 0) * chips(rec)
    return n_active * pbytes + 0.5 * cache  # cache ~ half the argument bytes


def analyse(rec) -> dict | None:
    if rec.get("status") != "ok":
        return None
    nchips = chips(rec)
    w = rec.get("weighted", {})
    flops_dev = w.get("flops", 0)
    bytes_dev = w.get("bytes", 0)
    coll_dev = rec.get("collectives", {}).get("total_bytes", 0)
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max(
        ("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(rec)
    useful = mf / nchips / max(flops_dev, 1)
    # Roofline fraction = useful time / bound time, where useful time is
    # the larger of ideal-compute (6ND at peak FLOPs) and ideal-memory
    # (every active param + cache byte moved once at peak BW).  Train cells
    # are compute-ideal; decode cells are legitimately bandwidth-ideal.
    t_ideal_c = mf / nchips / PEAK_FLOPS
    t_ideal_m = useful_bytes(rec) / nchips / HBM_BW
    t_ideal = max(t_ideal_c, t_ideal_m)
    t_bound = max(t_comp, t_mem, t_coll)
    frac = min(t_ideal / t_bound, 1.0) if t_bound > 0 else 0.0
    return dict(
        cell=f"{rec['arch']}/{rec['shape']}/{rec['mesh']}",
        t_compute=t_comp,
        t_memory=t_mem,
        t_collective=t_coll,
        dominant=dominant,
        model_flops=mf,
        useful_frac=useful,
        roofline_frac=frac,
        mem_args_gib=rec.get("memory", {}).get("argument_size_in_bytes", 0) / 2**30,
        mem_temp_gib=rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30,
    )


LEVERS = {
    "collective": "reshard/overlap the dominant collective (move MoE "
    "dispatch scatter onto the data axis; bf16 grad reduce)",
    "memory": "larger fused blocks / fewer remat passes; bf16 master or "
    "reduced optimizer traffic",
    "compute": "causal_skip to halve attention FLOPs; drop remat "
    "recompute where memory allows",
}


def main():
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        a = analyse(rec)
        if a is None:
            status = rec.get("status")
            print(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']},0.0,"
                  f"status={status}")
            continue
        rows.append(a)
        print(
            f"roofline/{a['cell']},0.0,"
            f"compute={a['t_compute']:.4f}s;memory={a['t_memory']:.4f}s;"
            f"collective={a['t_collective']:.4f}s;dominant={a['dominant']};"
            f"useful={a['useful_frac']:.2f};roofline={a['roofline_frac']:.3f}"
        )

    # markdown table for EXPERIMENTS.md
    out = [
        "| cell | compute s | memory s | collective s | dominant | "
        "MODEL/HLO | roofline frac | args GiB/dev | temp GiB/dev | lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in sorted(rows, key=lambda r: r["cell"]):
        out.append(
            f"| {a['cell']} | {a['t_compute']:.4f} | {a['t_memory']:.4f} | "
            f"{a['t_collective']:.4f} | {a['dominant']} | "
            f"{a['useful_frac']:.2f} | {a['roofline_frac']:.3f} | "
            f"{a['mem_args_gib']:.1f} | {a['mem_temp_gib']:.1f} | "
            f"{LEVERS[a['dominant']][:60]} |"
        )
    md = "\n".join(out)
    os.makedirs(os.path.join(RESULTS, ".."), exist_ok=True)
    with open(os.path.join(RESULTS, "..", "roofline.md"), "w") as f:
        f.write(md + "\n")
    return rows


if __name__ == "__main__":
    main()
