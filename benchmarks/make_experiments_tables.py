"""Generate the EXPERIMENTS.md §Dry-run table from the dry-run JSONs."""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def main():
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        rec = json.load(open(path))
        if "__" in os.path.basename(path)[:-5].split("__")[-1] or len(
            os.path.basename(path)[:-5].split("__")
        ) > 3:
            continue  # perf variants live in §Perf
        cell = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec["status"] == "skipped":
            rows.append(f"| {cell} | skipped | {rec['reason'][:58]} ||||")
            continue
        if rec["status"] != "ok":
            rows.append(f"| {cell} | ERROR | {rec.get('error', '')[:58]} ||||")
            continue
        mem = rec["memory"]
        coll = rec["collectives"]
        w = rec.get("weighted", {})
        rows.append(
            f"| {cell} | ok | args {mem.get('argument_size_in_bytes', 0) / 2**30:.2f} + "
            f"temp {mem.get('temp_size_in_bytes', 0) / 2**30:.2f} GiB/dev | "
            f"{w.get('flops', 0):.2e} | "
            f"{coll['total_bytes'] / 2**30:.2f} GiB "
            f"(ar {coll['per_op_bytes'].get('all-reduce', 0) / 2**30:.1f} / "
            f"ag {coll['per_op_bytes'].get('all-gather', 0) / 2**30:.1f} / "
            f"a2a {coll['per_op_bytes'].get('all-to-all', 0) / 2**30:.1f}) | "
            f"{rec['compile_s']:.0f}s |"
        )
    hdr = (
        "| cell | status | per-device memory | HLO FLOPs/dev "
        "(trip-weighted) | collective bytes/dev (per step) | compile |\n"
        "|---|---|---|---|---|---|"
    )
    out = hdr + "\n" + "\n".join(rows) + "\n"
    with open(os.path.join(RESULTS, "..", "dryrun_table.md"), "w") as f:
        f.write(out)
    print(out[:2000])
    print(f"... {len(rows)} rows -> results/dryrun_table.md")


if __name__ == "__main__":
    main()
