"""Benchmark C7 — k-way fan-out vs the pairwise merge tree.

Sweeps the bottom-up merge sort's ``fanout`` over {2, 4, 8, 16} against
the pairwise baseline (``fanout=2``, the seed's ``sort_key_val``) and
XLA's native ``jnp.sort``, plus the standalone k-way merge of k
presorted runs vs a fold of pairwise rank-merges.

Per pass an element does ``k-1`` binary searches instead of 1, but
there are ``log2(k)``-times fewer passes — and each pass's scatter and
output materialisation is the expensive part on CPU/TPU XLA, so larger
fan-outs win once n is big enough to amortise the search work.

Derived column: million elements sorted (or merged) per second.

``--guard [baseline.json]`` re-times only the ``kway_merge/*`` records
and exits 1 if any median regresses more than 10% against the checked-in
``BENCH_kway.json`` baseline — the no-regression lane of
``scripts/verify.sh --engine``.
"""

from __future__ import annotations

import json
import sys

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core.kway import merge_kway_ranked
from repro.core.mergesort import merge_runs_ranked, sort_key_val


def main(json_path: str | None = None):
    rng = np.random.default_rng(7)
    records: list[dict] = []

    def rec(name: str, us: float, **extra):
        records.append({"name": name, "us_per_call": us, **extra})

    # --- full sorts: fanout sweep vs pairwise vs jnp.sort ---------------
    for size in (1 << 16, 1 << 18, 1 << 20):
        keys = jnp.asarray(
            rng.integers(0, 1 << 30, size), jnp.int32
        )
        vals = jnp.arange(size, dtype=jnp.int32)

        def meps(us):
            return f"{size / us:.1f}Melem/s"

        base_us = None
        for fanout in (2, 4, 8, 16):
            fn = jax.jit(
                lambda k, v, f=fanout: sort_key_val(k, v, fanout=f)
            )
            us = time_fn(fn, keys, vals)
            tag = meps(us)
            if fanout == 2:
                base_us = us
            else:
                tag += f";vs_pairwise={base_us / us:.2f}x"
            row(f"kway_sort/fanout{fanout}/{size}", us, tag)
            rec(f"kway_sort/fanout{fanout}/{size}", us,
                melem_per_s=size / us, fanout=fanout, size=size)

        us = time_fn(jax.jit(lambda k: jnp.sort(k, stable=True)), keys)
        row(f"kway_sort/xla_native/{size}", us, meps(us))
        rec(f"kway_sort/xla_native/{size}", us,
            melem_per_s=size / us, size=size)

    # --- standalone k-run merge: one k-way pass vs pairwise fold --------
    for k, w in ((4, 1 << 16), (8, 1 << 15), (16, 1 << 14)):
        runs = jnp.asarray(
            np.sort(rng.integers(0, 1 << 30, (k, w)), axis=1), jnp.int32
        )
        total = k * w

        def pairwise_fold(runs):
            cur, width = runs, runs.shape[1]
            n = runs.shape[0]
            while n > 1:
                merged, _ = merge_runs_ranked(
                    cur.reshape(n // 2, 2, width), None
                )
                cur, n, width = merged, n // 2, width * 2
            return cur[0]

        us_k = time_fn(jax.jit(merge_kway_ranked), runs)
        us_p = time_fn(jax.jit(pairwise_fold), runs)
        row(f"kway_merge/kway/{k}x{w}", us_k,
            f"{total / us_k:.1f}Melem/s;vs_pairwise={us_p / us_k:.2f}x")
        row(f"kway_merge/pairwise_tree/{k}x{w}", us_p,
            f"{total / us_p:.1f}Melem/s")
        rec(f"kway_merge/kway/{k}x{w}", us_k, melem_per_s=total / us_k,
            k=k, width=w, vs_pairwise=us_p / us_k)
        rec(f"kway_merge/pairwise_tree/{k}x{w}", us_p,
            melem_per_s=total / us_p, k=k, width=w)

    # Pallas interpret mode is Python-speed; report once, small size.
    from repro.kernels.merge import merge_kway_pallas

    runs = jnp.asarray(
        np.sort(rng.integers(0, 1 << 30, (4, 1 << 10)), axis=1), jnp.int32
    )
    us = time_fn(lambda r: merge_kway_pallas(r, tile=512), runs)
    row(f"kway_merge/pallas_interpret/4x{1 << 10}", us,
        f"{(4 << 10) / us:.2f}Melem/s")
    rec(f"kway_merge/pallas_interpret/4x{1 << 10}", us,
        melem_per_s=(4 << 10) / us)

    if json_path:
        with open(json_path, "w") as f:
            json.dump({"records": records}, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}", flush=True)
    return records


def _merge_timers():
    """``{record name: () -> median µs}`` for just the ``kway_merge/*``
    records (same rng seed and shapes as :func:`main`, skipping the
    full-sort sweep)."""
    from repro.kernels.merge import merge_kway_pallas

    rng = np.random.default_rng(7)
    timers: dict = {}
    for k, w in ((4, 1 << 16), (8, 1 << 15), (16, 1 << 14)):
        runs = jnp.asarray(
            np.sort(rng.integers(0, 1 << 30, (k, w)), axis=1), jnp.int32
        )
        timers[f"kway_merge/kway/{k}x{w}"] = (
            lambda r=runs, **kw: time_fn(
                jax.jit(merge_kway_ranked), r, **kw
            )
        )
    runs = jnp.asarray(
        np.sort(rng.integers(0, 1 << 30, (4, 1 << 10)), axis=1), jnp.int32
    )
    timers[f"kway_merge/pallas_interpret/4x{1 << 10}"] = (
        lambda r=runs, **kw: time_fn(
            lambda x: merge_kway_pallas(x, tile=512), r, **kw
        )
    )
    return timers


def guard(baseline_path: str = "BENCH_kway.json", tol: float = 0.10) -> int:
    """Fail (return 1) if any ``kway_merge`` record regresses > ``tol``
    against the checked-in baseline.  The current measurement is the
    *minimum* over iterations (neighbour load only ever inflates a
    timing, so min is the load-robust statistic; a genuine code
    regression inflates every iteration including the min), and a
    record over threshold is re-timed once with 4x the iterations
    before it counts as a regression.  New records (absent from the
    baseline) pass trivially; speedups always pass."""
    with open(baseline_path) as f:
        baseline = {
            r["name"]: r["us_per_call"] for r in json.load(f)["records"]
        }
    failed = 0
    for name, timer in _merge_timers().items():
        base = baseline.get(name)
        if base is None:
            row(name, timer(), "no baseline — skipped")
            continue
        stats = timer()
        if stats.min_us / base > 1.0 + tol:
            stats = timer(iters=20)
        us = stats.min_us
        ratio = us / base
        ok = ratio <= 1.0 + tol
        row(name, us, f"baseline={base:.0f}us;x{ratio:.2f};"
            + ("ok" if ok else f"REGRESSION>{tol:.0%}"))
        failed += not ok
    if failed:
        print(f"# bench guard: {failed} record(s) regressed "
              f"beyond {tol:.0%}", flush=True)
    else:
        print("# bench guard: all kway_merge timings within "
              f"{tol:.0%} of baseline", flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    if "--guard" in sys.argv[1:]:
        rest = [a for a in sys.argv[1:] if a != "--guard"]
        sys.exit(guard(rest[0] if rest else "BENCH_kway.json"))
    main("BENCH_kway.json")
