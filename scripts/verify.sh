#!/usr/bin/env bash
# Tier-1 verification entry point.
#
#   scripts/verify.sh          # full tier-1 suite (the ROADMAP command)
#   scripts/verify.sh --fast   # skip @pytest.mark.slow subprocess tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--fast" ]]; then
    exec python -m pytest -q -m "not slow"
fi
exec python -m pytest -x -q
