#!/usr/bin/env bash
# Tier-1 verification entry point.
#
#   scripts/verify.sh                # full tier-1 suite (the ROADMAP command)
#   scripts/verify.sh --fast         # skip @pytest.mark.slow subprocess tests
#   scripts/verify.sh --distributed  # shard_map suites on 8 fake host devices
#                                    # (distributed merge/sort + exchange)
#   scripts/verify.sh --moe          # dropless dispatch: 8-device subprocess
#                                    # sweeps + single-device semantic checks
#   scripts/verify.sh --obs          # observability: HLO-invariance-when-off
#                                    # (tier-1 fails loudly if record points
#                                    # leak into disabled HLO) + the 8-device
#                                    # counter/JSONL acceptance run
#   scripts/verify.sh --serve        # continuous-batching serving: batched
#                                    # top-k/top-p bit-exactness vs the
#                                    # per-request references, scheduler/pool
#                                    # property tests, the e2e staggered-
#                                    # arrival smoke decode, and the
#                                    # serve_topk no-regression bench guard
#                                    # (vs BENCH_serve.json)
#   scripts/verify.sh --external     # out-of-core sort: tmpdir spill files,
#                                    # small chunks/windows forcing multi-pass
#                                    # merges, crash-resume + residency bounds;
#                                    # includes the @slow large sweep
#   scripts/verify.sh --engine       # one-engine equivalence sweep (device,
#                                    # 8-device collective, host planner and
#                                    # Pallas-interpret cuts bit-identical on
#                                    # the shared oracle cases) + the
#                                    # kway_merge no-regression bench guard
#                                    # (fail if a median regresses >10% vs
#                                    # BENCH_kway.json)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "${1:-}" in
    --fast)
        exec python -m pytest -q -m "not slow"
        ;;
    --distributed)
        # The child processes force 8 host devices themselves; exporting the
        # flag here also covers any future in-process shard_map tests.
        export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"
        exec python -m pytest -q tests/test_distributed.py tests/test_exchange.py
        ;;
    --moe)
        export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"
        exec python -m pytest -q tests/test_moe_dropless.py tests/test_moe_dispatch.py
        ;;
    --obs)
        # The 8-device acceptance run is a child process that forces its own
        # device count; the fast-lane HLO-identity tests run here too.
        exec python -m pytest -q tests/test_obs.py
        ;;
    --engine)
        # The 8-device lane is a child process that forces its own device
        # count; the bench guard re-times the kway_merge records against
        # the checked-in baseline.
        python -m pytest -q tests/test_engine.py
        exec python -m benchmarks.kway_throughput --guard
        ;;
    --serve)
        # The e2e smoke decode is a @slow subprocess test; the bench guard
        # re-times the serve_topk records against the checked-in baseline.
        python -m pytest -q tests/test_serving.py
        exec python -m benchmarks.serve_decode --guard
        ;;
    --external)
        # Spill files land in pytest tmpdirs; the suite's small chunk /
        # window / fanout settings force >= 2 merge passes everywhere the
        # multi-pass machinery matters.  Runs the slow sweep too.
        exec python -m pytest -q tests/test_external.py
        ;;
    *)
        exec python -m pytest -x -q
        ;;
esac
