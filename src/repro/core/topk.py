"""Top-k selection built on the co-rank merge primitive.

Two-stage tournament (the classic distributed-selection shape, with every
stage expressed as stable merges):

  1. split the row into blocks of ``block`` elements, merge-sort each block
     descending (vectorised over blocks),
  2. repeatedly *merge* adjacent blocks' candidate lists pairwise — after a
     merge only the top ``k`` of the ``2k`` candidates can survive, so each
     round halves the number of candidate lists at constant width ``k``.

Stability: equal keys resolve to the lower original index (A-run before
B-run, and in-block sort is stable), matching ``jax.lax.top_k`` semantics.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.mergesort import merge_pairs_ranked

__all__ = ["merge_topk"]


def _desc_sort_blocks(keys: jax.Array, vals: jax.Array):
    """Stable descending sort within each row of ``keys``/``vals`` (r, w)."""
    r, w = keys.shape
    width = 1
    k, v = keys, vals
    while width < w:
        runs = (r * w) // (2 * width)
        k2, v2 = merge_pairs_ranked(
            k.reshape(runs, 2, width), v.reshape(runs, 2, width)
        )
        k, v = k2.reshape(r, w), v2.reshape(r, w)
        width *= 2
    return k, v


@partial(jax.jit, static_argnames=("k", "block"))
def merge_topk(x: jax.Array, k: int, block: int = 128):
    """Top-k of a 1-D array: returns ``(values, indices)`` descending.

    Keys are negated so the underlying ascending stable merge yields a
    descending order with ties broken toward the lower index.
    """
    n = x.shape[0]
    block = max(block, k)
    nb = -(-n // block)
    pad = nb * block - n
    neg = -x.astype(jnp.float32) if jnp.issubdtype(x.dtype, jnp.floating) else -x
    sentinel = jnp.array(jnp.inf, neg.dtype) if jnp.issubdtype(
        neg.dtype, jnp.floating
    ) else jnp.array(jnp.iinfo(neg.dtype).max, neg.dtype)
    keys = jnp.concatenate([neg, jnp.full((pad,), sentinel, neg.dtype)])
    idx = jnp.arange(nb * block, dtype=jnp.int32)
    keys = keys.reshape(nb, block)
    idx = idx.reshape(nb, block)
    keys, idx = _desc_sort_blocks(keys, idx)  # ascending in negated keys
    keys, idx = keys[:, :k], idx[:, :k]  # per-block top-k candidates

    # Tournament: pairwise merge candidate lists, keep top-k each round.
    while keys.shape[0] > 1:
        r = keys.shape[0]
        if r % 2 == 1:  # odd: carry the last list through unchanged
            keys = jnp.concatenate(
                [keys, jnp.full((1, k), sentinel, keys.dtype)]
            )
            idx = jnp.concatenate([idx, jnp.zeros((1, k), idx.dtype)])
            r += 1
        mk, mi = merge_pairs_ranked(
            keys.reshape(r // 2, 2, k), idx.reshape(r // 2, 2, k)
        )
        keys, idx = mk[:, :k], mi[:, :k]

    vals = -keys[0]
    return vals.astype(x.dtype), idx[0]
