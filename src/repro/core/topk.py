"""Top-k selection built on the k-way co-rank merge primitive.

Two-stage tournament (the classic distributed-selection shape, with
every stage expressed as stable merges):

  1. split the row into blocks of ``block`` elements, merge-sort each
     block descending (vectorised over blocks),
  2. collapse all per-block candidate lists with a *k-way* candidate
     merge: groups of up to ``fanout`` lists merge in one co-ranked step
     and only the top ``k`` of each merged ``fanout*k`` list survive.
     With ``nb <= fanout`` blocks the whole tournament is a single k-way
     merge; otherwise it takes ``log_fanout(nb)`` rounds instead of the
     pairwise tree's ``log2(nb)``.

Stability: equal keys resolve to the lower original index (lower run
index wins ties in the k-way merge, and the in-block sort is stable),
matching ``jax.lax.top_k`` semantics.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.mergesort import (
    DEFAULT_FANOUT,
    _padded_pow2,
    merge_runs_ranked,
)

__all__ = ["merge_topk"]

# Candidate lists merged per tournament round; 16 collapses any
# realistic block count in one or two rounds.
TOURNAMENT_FANOUT = 16


def _desc_sort_blocks(keys: jax.Array, vals: jax.Array):
    """Stable ascending sort within each row of ``keys``/``vals`` (r, w)."""
    r, w = keys.shape
    width = 1
    k, v = keys, vals
    while width < w:
        group = min(DEFAULT_FANOUT, w // width)
        g = (r * w) // (group * width)
        k2, v2 = merge_runs_ranked(
            k.reshape(g, group, width), v.reshape(g, group, width)
        )
        k, v = k2.reshape(r, w), v2.reshape(r, w)
        width *= group
    return k, v


@partial(jax.jit, static_argnames=("k", "block", "fanout"))
def merge_topk(x: jax.Array, k: int, block: int = 128,
               fanout: int = 0):
    """Top-k of a 1-D array: returns ``(values, indices)`` descending.

    Keys are negated so the underlying ascending stable merge yields a
    descending order with ties broken toward the lower index.
    ``fanout=0`` (the config-field convention) means
    ``TOURNAMENT_FANOUT``.
    """
    fanout = fanout or TOURNAMENT_FANOUT
    if fanout < 2:
        raise ValueError(f"fanout must be >= 2, got {fanout}")
    n = x.shape[0]
    # power-of-two block so the in-block sort's run reshapes stay aligned
    block = _padded_pow2(max(block, k))
    nb = -(-n // block)
    pad = nb * block - n
    neg = -x.astype(jnp.float32) if jnp.issubdtype(x.dtype, jnp.floating) else -x
    sentinel = jnp.array(jnp.inf, neg.dtype) if jnp.issubdtype(
        neg.dtype, jnp.floating
    ) else jnp.array(jnp.iinfo(neg.dtype).max, neg.dtype)
    keys = jnp.concatenate([neg, jnp.full((pad,), sentinel, neg.dtype)])
    idx = jnp.arange(nb * block, dtype=jnp.int32)
    keys = keys.reshape(nb, block)
    idx = idx.reshape(nb, block)
    keys, idx = _desc_sort_blocks(keys, idx)  # ascending in negated keys
    keys, idx = keys[:, :k], idx[:, :k]  # per-block top-k candidates

    # Tournament: k-way merge candidate lists, keep top-k each round.
    while keys.shape[0] > 1:
        r = keys.shape[0]
        group = min(fanout, r)
        if r % group:  # pad with sentinel lists to a group multiple
            extra = group - r % group
            keys = jnp.concatenate(
                [keys, jnp.full((extra, k), sentinel, keys.dtype)]
            )
            idx = jnp.concatenate([idx, jnp.zeros((extra, k), idx.dtype)])
            r += extra
        mk, mi = merge_runs_ranked(
            keys.reshape(r // group, group, k), idx.reshape(r // group, group, k)
        )
        keys, idx = mk[:, :k], mi[:, :k]

    vals = -keys[0]
    return vals.astype(x.dtype), idx[0]
