"""Top-k selection built on the k-way co-rank merge primitive.

Two-stage tournament (the classic distributed-selection shape, with
every stage expressed as stable merges):

  1. split the row into blocks of ``block`` elements, merge-sort each
     block descending (vectorised over blocks),
  2. collapse all per-block candidate lists with a *k-way* candidate
     merge: groups of up to ``fanout`` lists merge in one co-ranked step
     and only the top ``k`` of each merged ``fanout*k`` list survive.
     With ``nb <= fanout`` blocks the whole tournament is a single k-way
     merge; otherwise it takes ``log_fanout(nb)`` rounds instead of the
     pairwise tree's ``log2(nb)``.

The batched form (:func:`merge_topk_batch`) runs the same tournament
over ``b`` rows at once: the batch is just a leading group dimension on
every block sort and candidate merge, so a whole decode batch's top-k
costs **one** ``merge_kway_ranked`` cut per round instead of ``b``
per-request tournaments — the serving-side formulation
(``repro.serving.sampling``).  Per-row results are bit-identical to the
single-row :func:`merge_topk` by construction: the row-wise operations
never read across rows (group reshapes always tile within a row).

Stability: equal keys resolve to the lower original index (lower run
index wins ties in the k-way merge, and the in-block sort is stable),
matching ``jax.lax.top_k`` semantics.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.mergesort import (
    DEFAULT_FANOUT,
    _padded_pow2,
    merge_runs_ranked,
    sentinel_max,
)

__all__ = [
    "merge_topk",
    "merge_topk_batch",
    "candidate_blocks",
    "tournament_rounds",
]

# Candidate lists merged per tournament round; 16 collapses any
# realistic block count in one or two rounds.
TOURNAMENT_FANOUT = 16


def _desc_sort_blocks(keys: jax.Array, vals: jax.Array):
    """Stable ascending sort within each row of ``keys``/``vals`` (r, w)."""
    r, w = keys.shape
    width = 1
    k, v = keys, vals
    while width < w:
        group = min(DEFAULT_FANOUT, w // width)
        g = (r * w) // (group * width)
        k2, v2 = merge_runs_ranked(
            k.reshape(g, group, width), v.reshape(g, group, width)
        )
        k, v = k2.reshape(r, w), v2.reshape(r, w)
        width *= group
    return k, v


def candidate_blocks(n: int, k: int, block: int = 128) -> tuple[int, int]:
    """Static stage-1 shape of the tournament for a row of ``n`` logits:
    ``(resolved block width, number of candidate runs)``.  The block is
    rounded to a power of two >= k so the in-block sort's run reshapes
    stay aligned."""
    block = _padded_pow2(max(block, k))
    return block, -(-n // block)


def tournament_rounds(nb: int, fanout: int = 0) -> list[int]:
    """Run counts *entering* each tournament round (after padding to a
    group multiple), for ``nb`` stage-1 candidate runs.

    ``len()`` of the result is the number of ``merge_kway_ranked`` cuts
    a top-k takes; the last entry times ``k`` is the candidate count of
    the final cut.  Empty when ``nb <= 1`` (no merging needed).  The
    serving layer records both as ``serve.topk_*`` metrics.
    """
    fanout = fanout or TOURNAMENT_FANOUT
    rounds = []
    r = nb
    while r > 1:
        group = min(fanout, r)
        if r % group:
            r += group - r % group
        rounds.append(r)
        r //= group
    return rounds


@partial(jax.jit, static_argnames=("k", "block", "fanout"))
def merge_topk_batch(x: jax.Array, k: int, block: int = 128,
                     fanout: int = 0):
    """Row-wise top-k of a 2-D array: ``(b, n) -> (values, indices)``,
    both ``(b, k)`` descending.

    The whole batch moves through every stage together: one vectorised
    block sort and one ``merge_runs_ranked`` call per tournament round,
    regardless of ``b`` — group reshapes tile strictly within rows, so
    row ``i`` of the result equals ``merge_topk(x[i], ...)`` bit for bit.

    Keys are negated so the underlying ascending stable merge yields a
    descending order with ties broken toward the lower index.
    ``fanout=0`` (the config-field convention) means
    ``TOURNAMENT_FANOUT``.
    """
    fanout = fanout or TOURNAMENT_FANOUT
    if fanout < 2:
        raise ValueError(f"fanout must be >= 2, got {fanout}")
    b, n = x.shape
    block, nb = candidate_blocks(n, k, block)
    pad = nb * block - n
    neg = -x.astype(jnp.float32) if jnp.issubdtype(x.dtype, jnp.floating) else -x
    sentinel = sentinel_max(neg.dtype)
    keys = jnp.concatenate(
        [neg, jnp.full((b, pad), sentinel, neg.dtype)], axis=1
    )
    idx = jnp.broadcast_to(
        jnp.arange(nb * block, dtype=jnp.int32), (b, nb * block)
    )
    keys, idx = _desc_sort_blocks(
        keys.reshape(b * nb, block), idx.reshape(b * nb, block)
    )  # ascending in negated keys
    # per-block top-k candidates: (b, nb, k)
    keys = keys.reshape(b, nb, block)[:, :, :k]
    idx = idx.reshape(b, nb, block)[:, :, :k]

    # Tournament: k-way merge candidate lists, keep top-k each round —
    # one cut for the whole batch per round.
    r = nb
    while r > 1:
        group = min(fanout, r)
        if r % group:  # pad with sentinel lists to a group multiple
            extra = group - r % group
            keys = jnp.concatenate(
                [keys, jnp.full((b, extra, k), sentinel, keys.dtype)], axis=1
            )
            idx = jnp.concatenate(
                [idx, jnp.zeros((b, extra, k), idx.dtype)], axis=1
            )
            r += extra
        mk, mi = merge_runs_ranked(
            keys.reshape(b * (r // group), group, k),
            idx.reshape(b * (r // group), group, k),
        )
        keys = mk.reshape(b, r // group, group * k)[:, :, :k]
        idx = mi.reshape(b, r // group, group * k)[:, :, :k]
        r //= group

    vals = -keys[:, 0]
    return vals.astype(x.dtype), idx[:, 0]


@partial(jax.jit, static_argnames=("k", "block", "fanout"))
def merge_topk(x: jax.Array, k: int, block: int = 128,
               fanout: int = 0):
    """Top-k of a 1-D array: returns ``(values, indices)`` descending.

    Single-row view of :func:`merge_topk_batch` (same tournament, same
    tie-breaking, same padding).
    """
    vals, idx = merge_topk_batch(x[None], k, block=block, fanout=fanout)
    return vals[0], idx[0]
