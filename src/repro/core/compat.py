"""JAX version compatibility shims (installed 0.4.x vs current APIs)."""

from __future__ import annotations

import jax
from jax import lax

__all__ = ["shard_map", "axis_size"]

try:  # JAX >= 0.6 exports shard_map at top level
    from jax import shard_map as _shard_map_impl
except ImportError:  # JAX 0.4.x keeps it under experimental
    from jax.experimental.shard_map import shard_map as _shard_map_impl


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-tolerant ``shard_map``: JAX 0.4.x needs ``check_rep=False``
    for while-loops inside the mapped fn (the co-rank searches); newer JAX
    renamed/removed the flag, so fall back to the plain call."""
    try:
        return _shard_map_impl(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
    except TypeError:
        return _shard_map_impl(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs
        )


def axis_size(axis_name):
    """Static size of a mapped axis, inside shard_map/pmap."""
    if hasattr(lax, "axis_size"):  # JAX >= 0.5
        return lax.axis_size(axis_name)
    if hasattr(jax.core, "axis_frame"):  # JAX 0.4.x: returns the int size
        return jax.core.axis_frame(axis_name)
    return lax.psum(1, axis_name)  # last resort: constant-folded collective
