"""Baselines the paper compares against — implemented for benchmarking.

1. ``equidistant_partition`` / ``merge_equidistant`` — the classic PRAM/BSP
   parallel merge (Shiloach-Vishkin / Hagerup-Rüb / BSP style): pick
   equidistant splitters in *both* arrays, cross-rank each by binary search,
   and let the 2p resulting segment pairs be merged independently.  Per-PE
   segments are bounded by ``ceil(m/p) + ceil(n/p)`` but can be as small as
   0, i.e. up to a **factor-2 load imbalance** versus the ideal
   ``(m+n)/p`` — the inefficiency the paper removes.  On TPU the imbalance
   becomes tile *padding*: a static-shape kernel must size every tile for
   the worst case, so ~2x VMEM and compute are wasted (see DESIGN.md §3).

2. ``merge_lexicographic`` — the standard stability workaround: merge on
   widened (key, origin, index) lexicographic keys.  Costs an extra index
   array, wider comparisons and the key-packing arithmetic; the paper's
   co-rank merge needs none of that.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.engine import SIDE_STRICT, SIDE_TIES

__all__ = [
    "equidistant_partition",
    "merge_equidistant",
    "merge_lexicographic",
    "partition_sizes_equidistant",
]


@partial(jax.jit, static_argnames=("p",))
def equidistant_partition(a: jax.Array, b: jax.Array, p: int):
    """Classic splitter-based co-partition.

    Returns ``(ja, ka, jb, kb)`` concatenated cut points: ``2p`` segments
    given by merging the p equidistant A-splitters (with their B
    cross-ranks) and the p equidistant B-splitters (with their A
    cross-ranks).  Output: arrays ``j_cuts, k_cuts`` of shape (2p+1,) with
    ``j_cuts[s] + k_cuts[s]`` the output offset of segment ``s``.
    """
    m, n = a.shape[0], b.shape[0]
    # Equidistant positions in A and B.
    ja = jnp.asarray([min(m, -(-m // p) * r) for r in range(p + 1)], jnp.int32)
    kb = jnp.asarray([min(n, -(-n // p) * r) for r in range(p + 1)], jnp.int32)
    # Cross-ranks via binary search (ties: consistent with stable merge —
    # the engine's sides: A splitters rank strictly into B, B splitters
    # rank past ties into A).
    ka = jnp.searchsorted(
        b, a[jnp.clip(ja, 0, m - 1)], side=SIDE_STRICT
    ).astype(jnp.int32)
    ka = jnp.where(ja >= m, n, ka).at[0].set(0)
    jb = jnp.searchsorted(
        a, b[jnp.clip(kb, 0, n - 1)], side=SIDE_TIES
    ).astype(jnp.int32)
    jb = jnp.where(kb >= n, m, jb).at[0].set(0)
    # Union of cut points, ordered by output offset (stable on ties).
    j_cuts = jnp.concatenate([ja, jb])
    k_cuts = jnp.concatenate([ka, kb])
    order = jnp.argsort(j_cuts + k_cuts, stable=True)
    j_cuts, k_cuts = j_cuts[order], k_cuts[order]
    # Drop the duplicated (0,0) start / (m,n) end by construction: keep 2p+1.
    return j_cuts[1:], k_cuts[1:]


@partial(jax.jit, static_argnames=("p",))
def partition_sizes_equidistant(a: jax.Array, b: jax.Array, p: int):
    """Per-segment output sizes of the classic partition (for the
    load-imbalance benchmark; ideal is (m+n)/(2p) per segment)."""
    j_cuts, k_cuts = equidistant_partition(a, b, p)
    off = j_cuts + k_cuts
    return jnp.diff(off)


@partial(jax.jit, static_argnames=("p",))
def merge_equidistant(a: jax.Array, b: jax.Array, p: int) -> jax.Array:
    """Classic equidistant-splitter parallel merge (stable).

    Static-shape realisation: every one of the 2p segments is merged in a
    lane padded to the worst-case segment size ``ceil(m/p) + ceil(n/p)`` —
    the factor-2 overhead the co-rank merge eliminates.
    """
    from repro.core.merge import merge_segment_twofinger

    m, n = a.shape[0], b.shape[0]
    j_cuts, k_cuts = equidistant_partition(a, b, p)
    seg_len = -(-m // p) + -(-n // p)  # worst case — the padding cost

    def one_seg(j_lo, j_hi, k_lo, k_hi):
        return merge_segment_twofinger(a, b, j_lo, j_hi, k_lo, k_hi, seg_len)

    segs = jax.vmap(one_seg)(
        j_cuts[:-1], j_cuts[1:], k_cuts[:-1], k_cuts[1:]
    )  # (2p, seg_len)
    off = j_cuts + k_cuts
    idx = off[:-1, None] + jnp.arange(seg_len)[None, :]
    valid = idx < off[1:, None]
    out = jnp.zeros((m + n,), dtype=jnp.result_type(a, b))
    out = out.at[jnp.where(valid, idx, m + n)].set(segs, mode="drop")
    return out


@jax.jit
def merge_lexicographic(a: jax.Array, b: jax.Array) -> jax.Array:
    """Stability via widened keys: sort (key, origin/index) pairs.

    The standard trick the paper renders unnecessary.  Implemented with the
    composite sort ``lax.sort`` over two operands — i.e. it pays for a
    second full-width comparison key and the sort is O((m+n) log(m+n))
    instead of O(m+n) merge work.
    """
    m, n = a.shape[0], b.shape[0]
    keys = jnp.concatenate([a, b])
    tie = jnp.arange(m + n, dtype=jnp.int32)  # global index encodes origin
    sorted_keys, _ = jax.lax.sort((keys, tie), num_keys=2)
    return sorted_keys
