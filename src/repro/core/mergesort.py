"""Stable merge sort built from the co-rank merge primitive.

Bottom-up merge sort with configurable fan-out: pass ``w`` merges groups
of ``fanout`` adjacent runs of width ``w`` into runs of width
``fanout*w`` with the k-way rank merge from ``repro.core.kway`` —
``log_fanout(n)`` passes instead of the pairwise tree's ``log2(n)``.
Every pass is stable (lower run index wins ties, and runs are laid out
in input order), so the whole sort is stable without key widening — the
property the MoE router and the sampling stack rely on.

The input is padded to the next power of two with ``+inf``-like
sentinels (dtype max), which sort to the tail and are sliced off.  All
passes are fully vectorised: the ``g`` groups of a pass are a leading
batch dimension, so a pass is one fused XLA op sequence.  Per pass an
element performs ``k-1`` binary searches but there are ``log_k``-fewer
passes (and fewer scatters / output materialisations), which is the
trade the k-way fan-out wins on — see ``benchmarks/kway_throughput.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kway import kway_positions

__all__ = [
    "merge_sort",
    "merge_argsort",
    "sort_key_val",
    "merge_pairs_ranked",
    "merge_runs_ranked",
    "sentinel_max",
    "DEFAULT_FANOUT",
]

# Pass fan-out used when callers don't specify one.  4 is the measured
# sweet spot on XLA CPU (half the passes of pairwise at only ~1.5x the
# comparison count); see benchmarks/kway_throughput.py.
DEFAULT_FANOUT = 4


def sentinel_max(dtype) -> jnp.ndarray:
    """Order-preserving padding value: sorts after every real element.
    The single definition every padding site uses (merge sort, Pallas
    kernels, the distributed exchange) — padding correctness everywhere
    depends on this exact value."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


_sentinel_max = sentinel_max  # internal alias kept for existing callers


def merge_runs_ranked(keys: jax.Array, vals: jax.Array | None):
    """Merge groups of adjacent sorted runs: ``keys`` has shape
    ``(g, k, w)`` where every ``keys[i, r]`` is sorted; returns
    ``(g, k*w)`` stably merged (lower ``r`` wins ties).  ``vals`` (same
    shape) is carried through the same permutation.
    """
    g, k, w = keys.shape
    pos = jax.vmap(kway_positions)(keys)  # (g, k, w)
    rows = jnp.arange(g, dtype=jnp.int32)[:, None]
    flat_pos = pos.reshape(g, k * w)
    out_k = jnp.zeros((g, k * w), dtype=keys.dtype)
    out_k = out_k.at[rows, flat_pos].set(
        keys.reshape(g, k * w), unique_indices=True
    )
    if vals is None:
        return out_k, None
    out_v = jnp.zeros((g, k * w), dtype=vals.dtype)
    out_v = out_v.at[rows, flat_pos].set(
        vals.reshape(g, k * w), unique_indices=True
    )
    return out_k, out_v


def merge_pairs_ranked(keys: jax.Array, vals: jax.Array | None):
    """Pairwise special case kept for callers and benchmarks:
    ``keys``/``vals`` of shape ``(r, 2, w)`` -> ``(r, 2w)``.
    """
    return merge_runs_ranked(keys, vals)


def _padded_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _check_fanout(fanout: int) -> int:
    """Validate and resolve a fan-out: 0 means 'library default' (the
    ModelConfig/DataConfig convention), so call sites can pass a config
    field straight through."""
    if not fanout:
        return DEFAULT_FANOUT
    if fanout < 2 or fanout & (fanout - 1):
        raise ValueError(
            f"fanout must be a power of two >= 2 (or 0 for the "
            f"default), got {fanout}"
        )
    return fanout


def sort_key_val(keys: jax.Array, vals: jax.Array,
                 fanout: int = DEFAULT_FANOUT):
    """Stable sort of ``(keys, vals)`` by ``keys`` (1-D), merge-sort based.

    ``fanout``: runs merged per pass (power of two; 0 = default).
    ``fanout=2`` is the paper's pairwise tree; larger fan-outs cut the
    pass count to ``log_fanout(n)``.
    """
    fanout = _check_fanout(fanout)
    n = keys.shape[0]
    if n <= 1:
        return keys, vals
    np2 = _padded_pow2(n)
    pad = np2 - n
    k = jnp.concatenate([keys, jnp.full((pad,), _sentinel_max(keys.dtype))])
    v = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    width = 1
    while width < np2:
        group = min(fanout, np2 // width)  # both powers of two: divides
        g = np2 // (group * width)
        k2, v2 = merge_runs_ranked(
            k.reshape(g, group, width), v.reshape(g, group, width)
        )
        k, v = k2.reshape(np2), v2.reshape(np2)
        width *= group
    return k[:n], v[:n]


def merge_sort(x: jax.Array, fanout: int = DEFAULT_FANOUT) -> jax.Array:
    """Stable merge sort of a 1-D array (k-way bottom-up passes)."""
    fanout = _check_fanout(fanout)
    n = x.shape[0]
    if n <= 1:
        return x
    np2 = _padded_pow2(n)
    k = jnp.concatenate([x, jnp.full((np2 - n,), _sentinel_max(x.dtype))])
    width = 1
    while width < np2:
        group = min(fanout, np2 // width)
        g = np2 // (group * width)
        k, _ = merge_runs_ranked(k.reshape(g, group, width), None)
        k = k.reshape(np2)
        width *= group
    return k[:n]


def merge_argsort(x: jax.Array, fanout: int = DEFAULT_FANOUT) -> jax.Array:
    """Stable argsort (equal keys keep input order) via sort_key_val."""
    _, idx = sort_key_val(x, jnp.arange(x.shape[0], dtype=jnp.int32), fanout)
    return idx


merge_sort_jit = jax.jit(merge_sort, static_argnames=("fanout",))
sort_key_val_jit = jax.jit(sort_key_val, static_argnames=("fanout",))
