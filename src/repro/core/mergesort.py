"""Stable merge sort built from the co-rank merge primitive.

Bottom-up merge sort: ``log2(n)`` passes; pass ``w`` merges adjacent runs of
width ``w`` into runs of width ``2w``.  Every pairwise merge is the stable
rank-merge from ``repro.core.merge`` (Lemma 1 applied element-wise), so the
whole sort is stable without key widening — the property the MoE router and
the sampling stack rely on.

The input is padded to the next power of two with ``+inf``-like sentinels
(dtype max), which sort to the tail and are sliced off.  All passes are fully
vectorised: the ``r`` runs of a pass are a leading batch dimension, so a pass
is one fused XLA op sequence, and the whole sort is ``O(n log^2 n)``
comparisons with depth ``O(log^2 n)`` — the standard EREW-style realisation
of the paper's merge on a vector machine.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["merge_sort", "merge_argsort", "sort_key_val", "merge_pairs_ranked"]


def _sentinel_max(dtype) -> jnp.ndarray:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def merge_pairs_ranked(keys: jax.Array, vals: jax.Array | None):
    """Merge adjacent sorted runs: ``keys`` has shape ``(r, 2, w)`` where
    ``keys[:, 0]`` and ``keys[:, 1]`` are each sorted; returns ``(r, 2w)``
    stably merged (run 0 wins ties).  ``vals`` (same shape) is carried.
    """
    a, b = keys[:, 0, :], keys[:, 1, :]
    r, w = a.shape
    # Element-wise co-ranks (Lemma 1): A uses side='left' (<=), B 'right' (<).
    pos_a = jnp.arange(w, dtype=jnp.int32)[None, :] + jax.vmap(
        lambda x, y: jnp.searchsorted(y, x, side="left")
    )(a, b).astype(jnp.int32)
    pos_b = jnp.arange(w, dtype=jnp.int32)[None, :] + jax.vmap(
        lambda x, y: jnp.searchsorted(y, x, side="right")
    )(b, a).astype(jnp.int32)
    out_k = jnp.zeros((r, 2 * w), dtype=keys.dtype)
    out_k = out_k.at[jnp.arange(r)[:, None], pos_a].set(a, unique_indices=True)
    out_k = out_k.at[jnp.arange(r)[:, None], pos_b].set(b, unique_indices=True)
    if vals is None:
        return out_k, None
    va, vb = vals[:, 0, :], vals[:, 1, :]
    out_v = jnp.zeros((r, 2 * w), dtype=vals.dtype)
    out_v = out_v.at[jnp.arange(r)[:, None], pos_a].set(va, unique_indices=True)
    out_v = out_v.at[jnp.arange(r)[:, None], pos_b].set(vb, unique_indices=True)
    return out_k, out_v


def _padded_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def sort_key_val(keys: jax.Array, vals: jax.Array):
    """Stable sort of ``(keys, vals)`` by ``keys`` (1-D), merge-sort based."""
    n = keys.shape[0]
    if n <= 1:
        return keys, vals
    np2 = _padded_pow2(n)
    pad = np2 - n
    k = jnp.concatenate([keys, jnp.full((pad,), _sentinel_max(keys.dtype))])
    v = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    width = 1
    while width < np2:
        runs = np2 // (2 * width)
        k2, v2 = merge_pairs_ranked(
            k.reshape(runs, 2, width), v.reshape(runs, 2, width)
        )
        k, v = k2.reshape(np2), v2.reshape(np2)
        width *= 2
    return k[:n], v[:n]


def merge_sort(x: jax.Array) -> jax.Array:
    """Stable merge sort of a 1-D array."""
    n = x.shape[0]
    if n <= 1:
        return x
    np2 = _padded_pow2(n)
    k = jnp.concatenate([x, jnp.full((np2 - n,), _sentinel_max(x.dtype))])
    width = 1
    while width < np2:
        runs = np2 // (2 * width)
        k, _ = merge_pairs_ranked(k.reshape(runs, 2, width), None)
        k = k.reshape(np2)
        width *= 2
    return k[:n]


def merge_argsort(x: jax.Array) -> jax.Array:
    """Stable argsort (equal keys keep input order) via sort_key_val."""
    _, idx = sort_key_val(x, jnp.arange(x.shape[0], dtype=jnp.int32))
    return idx


merge_sort_jit = jax.jit(merge_sort)
sort_key_val_jit = jax.jit(sort_key_val)
