"""Multi-way co-ranking and perfectly load-balanced k-way stable merge.

Generalises the paper's two-sequence co-rank (Siebert & Träff 2013,
Lemma 1) to ``k`` sorted runs, following the "Multi-Way Co-Ranking"
formulation (Joshi 2025) and the diagonal view of Merge Path (Green et
al. 2014): an output rank ``i`` induces a unique *cut vector*
``(j_0, ..., j_{k-1})`` with ``sum(j_r) == i`` such that the first ``i``
elements of the stable k-way merge are exactly
``runs[0][:j_0] ∪ ... ∪ runs[k-1][:j_{k-1}]``.

Stability is "run index breaks ties": element ``(r, t)`` precedes
``(r', t')`` iff ``(value, r, t) < (value', r', t')`` lexicographically.
Under that order the *merged rank* of element ``(r, t)`` is

    rank(r, t) = t + sum_{r' < r} |{u : runs[r'][u] <= runs[r][t]}|
                   + sum_{r' > r} |{u : runs[r'][u] <  runs[r][t]}|

— the ``<=`` / ``<`` asymmetry is exactly Lemma 1's, applied pairwise to
every other run.  ``rank(r, ·)`` is strictly increasing, so the cut

    j_r(i) = |{t : rank(r, t) < i}|

is found by one binary search per run whose predicate evaluates the
k-way Lemma-1 conditions (``ceil(log2 w)+1`` rounds, each round ``k``
``searchsorted`` probes — all runs search in lock-step, vectorised).
``sum_r j_r(i) == i`` holds exactly because ``rank`` is a bijection onto
``0..k*w-1``.

On top of the cut sit two merges:

* ``merge_kway_ranked`` — fully data-parallel: every element's output
  position is its merged rank (k-1 vectorised ``searchsorted`` per run),
  one scatter.  The fast pure-XLA path used by the fan-out merge sort.
* ``merge_kway`` — the paper-faithful partitioned form: ``p`` processing
  elements each co-rank the two endpoints of an output block of size
  ``ceil(total/p)`` (perfect balance, Proposition 2 carries over
  verbatim) and run a sequential k-finger merge of exactly their
  segments.

Ragged runs are supported via ``lengths``: rows must stay sorted over
their full width (pad with a value >= every real element, e.g. dtype
max); padded positions are never counted or emitted.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs
from repro.core import engine
from repro.core.engine import SIDE_STRICT, SIDE_TIES
from repro.core.merge import partition_bounds

__all__ = [
    "co_rank_kway",
    "co_rank_kway_batch",
    "kway_positions",
    "merge_kway_ranked",
    "merge_kway",
]


class _DenseProbe:
    """Engine probe over an on-device ``(k, w)`` run array.

    ``values`` is one clamped gather; ``counts`` is a vmapped
    ``searchsorted`` per Lemma-1 side; the loop lowers as a static
    ``lax.fori_loop`` (jit/vmap-safe).  See ``repro.core.engine`` for
    the protocol.
    """

    xp = jnp
    run_loop = staticmethod(engine.run_fori)

    def __init__(self, runs: jax.Array, lengths: jax.Array):
        k, w = runs.shape
        self.runs = runs
        self.width = w
        self.lengths = lengths  # (k,)
        self.owner_ids = jnp.arange(k, dtype=jnp.int32)[:, None]
        self.query_ids = jnp.arange(k, dtype=jnp.int32)[None, :]
        self.owner_lengths = lengths[:, None]
        self._rows = jnp.arange(k, dtype=jnp.int32)

    def init_bounds(self, i):
        k = self.runs.shape[0]
        return jnp.zeros((k,), jnp.int32), self.lengths

    def values(self, t):
        return self.runs[self._rows, jnp.clip(t, 0, self.width - 1)]

    def counts(self, x):
        le = jax.vmap(lambda row: jnp.searchsorted(row, x, side=SIDE_TIES))(
            self.runs
        ).astype(jnp.int32)
        lt = jax.vmap(lambda row: jnp.searchsorted(row, x, side=SIDE_STRICT))(
            self.runs
        ).astype(jnp.int32)
        return le, lt

    def reduce(self, cnt):
        return cnt.sum(axis=0)


def co_rank_kway(
    i: jax.Array, runs: jax.Array, lengths: jax.Array | None = None
) -> jax.Array:
    """Cut vector ``j`` (shape ``(k,)``) of output rank ``i`` into ``runs``.

    The dense-array instantiation of ``engine.co_rank_search`` — the
    lock-step k-way Lemma-1 bisection, ``kway_round_bound(w)`` rounds of
    ``k`` vectorised ``searchsorted`` probes.

    Args:
      i: output rank, ``0 <= i <= sum(lengths)`` (scalar, may be traced).
      runs: ``(k, w)`` array, every row sorted ascending over its full
        width (pad ragged rows with row-wise maximal values).
      lengths: optional ``(k,)`` real lengths; defaults to ``w`` each.

    Returns:
      int32 ``(k,)`` cut indices with ``j.sum() == min(i, total)``; the
      stable k-way merge of the runs restricted to ``runs[r][:j[r]]`` is
      exactly its first ``i`` elements.
    """
    k, w = runs.shape
    i = jnp.asarray(i, jnp.int32)
    if lengths is None:
        lengths = jnp.full((k,), w, jnp.int32)
    else:
        lengths = jnp.asarray(lengths, jnp.int32)
    return engine.co_rank_search(
        i,
        _DenseProbe(runs, lengths),
        metric="kway.corank_rounds",
        labels={"k": k, "w": w},
    )


def co_rank_kway_batch(
    i: jax.Array, runs: jax.Array, lengths: jax.Array | None = None
) -> jax.Array:
    """Vectorised cuts for ranks ``i`` of shape ``(b,)`` -> ``(b, k)``."""
    return jax.vmap(co_rank_kway, in_axes=(0, None, None))(i, runs, lengths)


def kway_positions(
    runs: jax.Array, lengths: jax.Array | None = None
) -> jax.Array:
    """Merged rank of *every* element: ``(k, w) -> (k, w)`` int32.

    The element-wise form of the cut characterisation — the k-way
    generalisation of ``merge_by_ranking``'s position computation.  Each
    element is searched into exactly its ``k-1`` sibling runs (the
    Python loop over runs unrolls at trace time; every probe is a
    vectorised ``searchsorted``).  Positions of padded elements
    (``t >= lengths[r]``) are meaningless; callers mask them before
    scattering.
    """
    k, w = runs.shape
    if lengths is None:
        # Hot path (uniform runs): element (r, t) is searched into each
        # sibling rp once — runs after rp count ties into rp (SIDE_TIES),
        # runs before it count strictly (SIDE_STRICT): Lemma 1 applied
        # pairwise, sides from the engine's one tie-break definition.
        cnt = jnp.zeros((k, w), jnp.int32)
        for rp in range(k):
            row = runs[rp]
            if rp + 1 < k:
                cr = jnp.searchsorted(row, runs[rp + 1 :], side=SIDE_TIES)
                cnt = cnt.at[rp + 1 :].add(cr.astype(jnp.int32))
            if rp > 0:
                cl = jnp.searchsorted(row, runs[:rp], side=SIDE_STRICT)
                cnt = cnt.at[:rp].add(cl.astype(jnp.int32))
    else:
        # Ragged runs: same incremental loop, with each source row's
        # counts clipped at its real length.  Exact because padding is
        # >= every real element (the row contract): a query can only
        # tie with padding when it equals the row's maximal real value,
        # and the clip restores exactly the real count there; padding
        # is never strictly below any query, so the left side needs no
        # correction at all (clipped anyway for symmetry).
        lengths = jnp.asarray(lengths, jnp.int32)
        cnt = jnp.zeros((k, w), jnp.int32)
        for rp in range(k):
            row = runs[rp]
            cap = lengths[rp]
            if rp + 1 < k:
                cr = jnp.searchsorted(row, runs[rp + 1 :], side=SIDE_TIES)
                cnt = cnt.at[rp + 1 :].add(
                    jnp.minimum(cr.astype(jnp.int32), cap)
                )
            if rp > 0:
                cl = jnp.searchsorted(row, runs[:rp], side=SIDE_STRICT)
                cnt = cnt.at[:rp].add(
                    jnp.minimum(cl.astype(jnp.int32), cap)
                )
    return jnp.arange(w, dtype=jnp.int32)[None, :] + cnt


def merge_kway_ranked(
    runs: jax.Array,
    vals: jax.Array | None = None,
    lengths: jax.Array | None = None,
    out_len: int | None = None,
):
    """Stable k-way merge, data-parallel scatter formulation.

    ``runs``: ``(k, w)`` sorted rows (+ optional ``vals`` payload of the
    same shape, carried through).  Returns the merged ``(total,)`` keys
    (and payload), ``total = out_len or k*w``; with ``lengths`` given,
    padded elements are dropped and the tail of the output (positions
    ``>= sum(lengths)``) is zero.
    """
    k, w = runs.shape
    total = k * w if out_len is None else out_len
    pos = kway_positions(runs, lengths)
    if lengths is not None:
        invalid = jnp.arange(w, dtype=jnp.int32)[None, :] >= jnp.asarray(
            lengths, jnp.int32
        )[:, None]
        pos = jnp.where(invalid, total, pos)  # scatter-dropped
    flat_pos = pos.reshape(-1)
    out = jnp.zeros((total,), runs.dtype)
    out = out.at[flat_pos].set(runs.reshape(-1), mode="drop")
    if vals is None:
        return out
    out_v = jnp.zeros((total,), vals.dtype)
    out_v = out_v.at[flat_pos].set(vals.reshape(-1), mode="drop")
    return out, out_v


def _kfinger_segment(
    runs: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    seg_len: int,
) -> jax.Array:
    """Sequential k-finger stable merge of ``runs[r][lo_r:hi_r]`` into a
    static ``(seg_len,)`` buffer (the per-PE "optimal sequential merge");
    ``sum(hi - lo) <= seg_len``.  ``fori_loop`` body so it vmaps across
    processing elements.
    """
    k, w = runs.shape
    rows = jnp.arange(k, dtype=jnp.int32)

    def step(t, state):
        cur, out = state
        vals = runs[rows, jnp.clip(cur, 0, w - 1)]
        avail = cur < hi
        # Fold min with availability flags: the engine's k-finger rule
        # (strict '<') keeps the earliest run on ties — the run-index
        # stability rule — and avoids any sentinel that could collide
        # with real dtype-max values.
        best_val, best_q, best_ok = vals[0], jnp.int32(0), avail[0]
        for q in range(1, k):
            better = engine.kfinger_better(vals[q], best_val, avail[q], best_ok)
            best_val = jnp.where(better, vals[q], best_val)
            best_q = jnp.where(better, jnp.int32(q), best_q)
            best_ok = best_ok | avail[q]
        out = lax.dynamic_update_index_in_dim(
            out, jnp.where(best_ok, best_val, out[t]), t, 0
        )
        cur = cur + ((rows == best_q) & best_ok)
        return cur, out

    out = jnp.zeros((seg_len,), runs.dtype)
    _, out = lax.fori_loop(0, seg_len, step, (lo, out))
    return out


@partial(jax.jit, static_argnames=("p",))
def merge_kway(runs: jax.Array, p: int = 8) -> jax.Array:
    """Perfectly load-balanced stable merge of ``k`` sorted runs.

    Algorithm 2 with the pairwise co-rank replaced by the multi-way cut:
    each of ``p`` processing elements co-ranks both endpoints of its
    output block (sizes differ by at most one, Proposition 2) and merges
    exactly its ``k`` input segments with a sequential k-finger merge.
    One partitioning step for any ``k`` — no ``log2(k)`` pairwise tree.
    """
    k, w = runs.shape
    total = k * w
    with obs.span("repro.merge_kway"):
        bounds = partition_bounds(total, p)  # (p+1,)
        cuts = co_rank_kway_batch(bounds, runs)  # (p+1, k)
        seg_len = -(-total // p)

        if obs.enabled():
            # Proposition 2 at runtime: per-PE output block sizes differ
            # by at most one (and the cut rows sum to the block bounds).
            sizes = bounds[1:] - bounds[:-1]
            obs.gauge("kway.partition_sizes", sizes, k=k, w=w, p=p)
            obs.gauge(
                "kway.partition_imbalance", sizes.max() - sizes.min(), p=p
            )

        segs = jax.vmap(
            lambda lo, hi: _kfinger_segment(runs, lo, hi, seg_len)
        )(cuts[:-1], cuts[1:])  # (p, seg_len)

        idx = (
            bounds[:-1, None] + jnp.arange(seg_len, dtype=jnp.int32)[None, :]
        )
        valid = idx < bounds[1:, None]
        out = jnp.zeros((total,), runs.dtype)
        out = out.at[jnp.where(valid, idx, total)].set(segs, mode="drop")
        return out
