"""Core: the paper's contribution — co-ranking and load-balanced stable merge."""

from repro.core.corank import CoRankResult, co_rank, co_rank_batch
from repro.core.merge import (
    merge_by_ranking,
    merge_partitioned,
    merge_segment_twofinger,
    partition_bounds,
)
from repro.core.kway import (
    co_rank_kway,
    co_rank_kway_batch,
    kway_positions,
    merge_kway,
    merge_kway_ranked,
)
from repro.core.mergesort import (
    merge_argsort,
    merge_pairs_ranked,
    merge_runs_ranked,
    merge_sort,
    sort_key_val,
)
from repro.core.topk import merge_topk
from repro.core.baselines import (
    equidistant_partition,
    merge_equidistant,
    merge_lexicographic,
    partition_sizes_equidistant,
)

__all__ = [
    "CoRankResult",
    "co_rank",
    "co_rank_batch",
    "merge_by_ranking",
    "merge_partitioned",
    "merge_segment_twofinger",
    "partition_bounds",
    "co_rank_kway",
    "co_rank_kway_batch",
    "kway_positions",
    "merge_kway",
    "merge_kway_ranked",
    "merge_argsort",
    "merge_pairs_ranked",
    "merge_runs_ranked",
    "merge_sort",
    "sort_key_val",
    "merge_topk",
    "equidistant_partition",
    "merge_equidistant",
    "merge_lexicographic",
    "partition_sizes_equidistant",
]
