"""Deprecated location — the distributed layer grew into a subsystem.

This module used to hold the whole multi-device story in one file; it is
now a thin re-export of ``repro.distributed`` (splitters / exchange /
api), kept so existing imports keep working.  The three strategies and
their memory-traffic trade-offs, in brief (full discussion in
``repro.distributed.api``):

* ``allgather`` — replicate the runs (one ``all_gather``, ``O(N)``
  memory and receive bytes per device), co-rank and merge the local
  block.  Simplest; caps scaling at single-device memory.
* ``corank`` — distribute the co-rank *search* (``O(log)`` rounds of
  ``O(p)``-scalar collectives), still gather the data windows.  Same
  ``O(N)`` data traffic; proves the search needs no replication.
* ``exchange`` — distributed k-way splitters (``O(log(N/p))`` rounds,
  ``O(p^2)`` scalars each) + balanced ``all_to_all`` (each device
  receives exactly its ``N/p``-element block) + local ragged k-way
  merge.  ``O(N/p)`` real payload per device; no full-``N``
  ``all_gather`` of values anywhere.

New code should import from ``repro.distributed`` directly.
"""

from repro.distributed.api import (  # noqa: F401
    distributed_merge,
    distributed_merge_corank,
    distributed_sort,
    sharded_merge_kway,
    sharded_sort,
    sharded_sort_host,
)
from repro.distributed.splitters import (  # noqa: F401
    distributed_co_rank,
    distributed_co_rank_kway,
)

__all__ = [
    "distributed_merge",
    "distributed_merge_corank",
    "distributed_co_rank",
    "distributed_co_rank_kway",
    "distributed_sort",
    "sharded_merge_kway",
    "sharded_sort",
    "sharded_sort_host",
]
