"""Distributed (multi-device) merge and sort on a shard_map mesh.

Three layers, increasingly faithful to the paper's distributed setting
(cf. Siebert & Träff's MPI companion paper [13]):

* ``distributed_merge(strategy='allgather')`` — CREW-PRAM emulation: one
  ``all_gather`` replicates A and B; every device co-ranks *its own* output
  block (Algorithm 2 verbatim, device id = processing element id) and
  merges exactly ``(m+n)/p`` elements.  Right choice when the merged data
  is consumed device-locally (routing metadata, sampler state).

* ``distributed_co_rank`` — Algorithm 1 executed over collectives *without
  gathering any array*: each binary-search step performs the two remote
  reads ``A[j-1]``, ``B[k]`` by publishing the wanted global index
  (``all_gather`` of p int32) and answering with a masked ``psum`` — the
  owner contributes the value, everyone else zero.  ``O(log min(m,n))``
  rounds of ``O(p)``-byte collectives; the paper's synchronization-free
  claim becomes "p independent searches batched into one SPMD program".

* ``distributed_sort`` — local merge sort, then *exact* global splitters
  via distributed co-rank on value space, then a capacity-1 ``all_to_all``
  exchange and a final local multi-run merge.  Because splitters are exact
  (the paper's perfect balance), every device receives exactly ``N/p``
  elements — the all_to_all is balanced *by construction*, unlike sample
  sort's 2x capacity slack.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.corank import co_rank
from repro.core.kway import co_rank_kway_batch, merge_kway_ranked
from repro.core.merge import merge_by_ranking
from repro.core.mergesort import merge_sort

__all__ = [
    "distributed_merge",
    "distributed_co_rank",
    "distributed_sort",
]


from repro.core.compat import axis_size as _axis_size  # noqa: E402


# ---------------------------------------------------------------------------
# allgather strategy (CREW emulation)
# ---------------------------------------------------------------------------


def distributed_merge(
    a_shard: jax.Array,
    b_shard: jax.Array,
    axis_name: str,
    strategy: Literal["allgather"] = "allgather",
) -> jax.Array:
    """Stable merge of two sorted, evenly sharded arrays.

    Call inside ``shard_map``.  ``a_shard``/``b_shard`` are this device's
    contiguous shards; the global arrays are their concatenations in device
    order.  Returns this device's contiguous shard of the merged output
    (size ``(m+n)/p``; ``m+n`` must be divisible by ``p`` — framework
    callers pad with sentinels upstream).
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    a = lax.all_gather(a_shard, axis_name, tiled=True)
    b = lax.all_gather(b_shard, axis_name, tiled=True)
    m, n = a.shape[0], b.shape[0]
    total = m + n
    assert total % p == 0, "pad inputs so p divides m+n"
    s = total // p

    i_lo = r * s
    j_lo, k_lo, _ = co_rank(i_lo, a, b)
    j_hi, k_hi, _ = co_rank(i_lo + s, a, b)

    # Static-size windows of length s cover the exact segments
    # (la + lb == s).  Out-of-segment lanes are masked to +sentinel so the
    # first s merged outputs are exactly this block.
    aw = _window(a, j_lo, j_hi, s)
    bw = _window(b, k_lo, k_hi, s)
    return merge_by_ranking(aw, bw)[:s]


def _sentinel(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def _window(x: jax.Array, lo, hi, s: int) -> jax.Array:
    """x[lo:hi] placed at the head of a length-s buffer, tail = sentinel."""
    n = x.shape[0]
    xp = jnp.concatenate([x, jnp.full((s,), _sentinel(x.dtype))])
    w = lax.dynamic_slice(xp, (jnp.minimum(lo, n),), (s,))
    mask = jnp.arange(s, dtype=jnp.int32) < (hi - lo)
    return jnp.where(mask, w, _sentinel(x.dtype))


# ---------------------------------------------------------------------------
# fully distributed co-rank (no data movement beyond O(p) scalars/round)
# ---------------------------------------------------------------------------


def _remote_read(shard: jax.Array, gidx: jax.Array, axis_name: str):
    """Every device reads global element ``gidx`` (its own request) from the
    sharded array: publish indices, owners answer via masked psum.

    Out-of-range ``gidx`` (sentinel reads A[-1], A[m]) return +/-inf codes
    handled by the caller; here we clamp and also return validity.
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    sz = shard.shape[0]  # local shard size (uniform)
    wanted = lax.all_gather(gidx, axis_name)  # (p,) every device's request
    owner = jnp.clip(wanted // sz, 0, p - 1)
    local = jnp.where(owner == r, wanted - r * sz, 0)
    vals = shard[jnp.clip(local, 0, sz - 1)]  # (p,) my answers
    answers = lax.psum(
        jnp.where(owner == r, vals, jnp.zeros_like(vals)), axis_name
    )
    return answers[r]


def distributed_co_rank(
    i: jax.Array, a_shard: jax.Array, b_shard: jax.Array, axis_name: str
):
    """Algorithm 1 with remote reads over collectives (per-device rank i).

    Each device searches for the co-ranks of its own ``i``; the p searches
    run in lock-step rounds (a fixed ``ceil(log2 min(m,n)) + 2`` count so
    the loop is static).  Returns ``(j, k)`` global co-ranks.
    """
    p = _axis_size(axis_name)
    m = a_shard.shape[0] * p
    n = b_shard.shape[0] * p
    i = jnp.asarray(i, jnp.int32)

    j = jnp.minimum(i, m)
    k = i - j
    j_low = jnp.maximum(jnp.int32(0), i - n)
    # k_low is derived from i so its shard_map varying-axes type matches
    # the loop body's output (i is per-device inside shard_map).
    k_low = i * 0

    rounds = max(1, min(m, n).bit_length() + 2)

    def body(_, state):
        j, k, j_low, k_low = state
        a_jm1 = _remote_read(a_shard, jnp.maximum(j - 1, 0), axis_name)
        b_k = _remote_read(b_shard, jnp.minimum(k, n - 1), axis_name)
        b_km1 = _remote_read(b_shard, jnp.maximum(k - 1, 0), axis_name)
        a_j = _remote_read(a_shard, jnp.minimum(j, m - 1), axis_name)

        fv = (j > 0) & (k < n) & (a_jm1 > b_k)
        sv = (k > 0) & (j < m) & (b_km1 >= a_j)
        active = fv | sv

        delta_j = (j - j_low + 1) // 2
        delta_k = (k - k_low + 1) // 2
        new_k_low = jnp.where(fv, k, k_low)
        new_j_low = jnp.where(fv | ~sv, j_low, j)
        new_j = jnp.where(fv, j - delta_j, jnp.where(sv, j + delta_k, j))
        new_k = jnp.where(fv, k + delta_j, jnp.where(sv, k - delta_k, k))
        del active
        return new_j, new_k, new_j_low, new_k_low

    j, k, _, _ = lax.fori_loop(0, rounds, body, (j, k, j_low, k_low))
    return j, k


def distributed_merge_corank(
    a_shard: jax.Array, b_shard: jax.Array, axis_name: str
) -> jax.Array:
    """Merge with distributed co-rank for the partition (data still fetched
    with one all_gather for the local windows; the *search* is distributed —
    this is the faithful [13]-style split of search vs. data movement)."""
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    m = a_shard.shape[0] * p
    n = b_shard.shape[0] * p
    total = m + n
    s = total // p
    j_lo, k_lo = distributed_co_rank(r * s, a_shard, b_shard, axis_name)
    j_hi, k_hi = distributed_co_rank(
        jnp.minimum((r + 1) * s, total), a_shard, b_shard, axis_name
    )
    a = lax.all_gather(a_shard, axis_name, tiled=True)
    b = lax.all_gather(b_shard, axis_name, tiled=True)
    aw = _window(a, j_lo, j_hi, s)
    bw = _window(b, k_lo, k_hi, s)
    return merge_by_ranking(aw, bw)[:s]


# ---------------------------------------------------------------------------
# distributed sort (local sort + exact splitters + balanced exchange)
# ---------------------------------------------------------------------------


def distributed_sort(x_shard: jax.Array, axis_name: str) -> jax.Array:
    """Globally stable sort of an evenly sharded array.

    1. local stable merge sort;
    2. all_gather of locally sorted shards (ring on ICI);
    3. every device extracts *its exact output block* in ONE step with
       the multi-way co-rank: the two block bounds are cut into all ``p``
       sorted runs at once (``repro.core.kway``), and the p segments —
       whose lengths sum to exactly N/p, perfect balance — are merged
       locally with the k-way rank merge.  No ``log2(p)`` pairwise merge
       tree, and each device merges only its own N/p elements instead of
       materialising the full N-element sort.

    Stability across shards: device order breaks ties (shard d's elements
    precede shard d+1's equal elements), matching a global stable sort.
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    local = merge_sort(x_shard)
    runs = lax.all_gather(local, axis_name)  # (p, N/p) sorted runs, in order
    np_, width = runs.shape
    total = np_ * width
    s = total // p

    # Both block endpoints cut in one lock-step batched search.
    cuts = co_rank_kway_batch(jnp.stack([r * s, (r + 1) * s]), runs)
    lo, hi = cuts[0], cuts[1]  # (p,) cuts of block start / end

    # Per-run windows of static length s (head = real segment, tail =
    # sentinel); segment lengths hi-lo sum to exactly s.
    windows = jax.vmap(lambda row, a, b: _window(row, a, b, s))(runs, lo, hi)
    return merge_kway_ranked(windows, lengths=hi - lo, out_len=s)
