"""Deprecated location — import from ``repro.distributed`` instead.

This module used to hold the whole multi-device story in one file; the
distributed layer grew into a subsystem (``repro.distributed.splitters``
/ ``exchange`` / ``api``).  Nothing lives here anymore: this is a pure
re-export shim kept so old imports keep working, and it warns on import.
"""

import warnings

from repro.distributed.api import (  # noqa: F401
    distributed_merge,
    distributed_merge_corank,
    distributed_sort,
    sharded_merge_kway,
    sharded_sort,
    sharded_sort_host,
)
from repro.distributed.splitters import (  # noqa: F401
    distributed_co_rank,
    distributed_co_rank_kway,
)

warnings.warn(
    "repro.core.distributed is deprecated; import from repro.distributed "
    "(api / splitters) instead.",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = [
    "distributed_merge",
    "distributed_merge_corank",
    "distributed_co_rank",
    "distributed_co_rank_kway",
    "distributed_sort",
    "sharded_merge_kway",
    "sharded_sort",
    "sharded_sort_host",
]
