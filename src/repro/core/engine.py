"""One co-rank engine: the paper's search, defined exactly once.

Every tier of this repo runs the *same* algorithm — the stable co-rank
search of Siebert & Träff (2013) — against a different way of reading
the runs:

======================  =====================================  ==========
tier                    probe / reads                          loop
======================  =====================================  ==========
``core.corank``         local array indexing                   dynamic ``lax.while_loop`` (Prop.-1 counted)
``core.kway``           vectorised ``searchsorted`` (k, w)     static ``lax.fori_loop``
``distributed``         ``all_gather`` + masked ``psum``       static ``lax.fori_loop`` (lock-step collective rounds)
``external.planner``    ``np.searchsorted`` over mmap'd runs   plain Python loop
``kernels.merge``       staged VMEM windows, per-lane search   unrolled ``fori_loop`` inside the Pallas kernel
======================  =====================================  ==========

This module is the single definition site for everything those tiers
must agree on bit-for-bit:

* the **Lemma-1 predicates** and the stability tie-break — the
  ``<=`` / ``<`` asymmetry lives *only* here (:func:`count_below` and
  the helpers built on it); every other module selects a side through
  :func:`counts_ties` / :func:`count_side` / :func:`lemma1_counts` or
  takes a merge decision through :func:`take_first` /
  :func:`kfinger_better`;
* the **lock-step k-way bisection loop** (:func:`co_rank_search`),
  parameterized by a :class:`Probe`;
* the **pairwise Algorithm 1** double-ended search
  (:func:`co_rank_pairwise`), parameterized by two read functions;
* the **padding/length clamp** (padded tail positions are never
  counted — the ``owner_length`` clip in :func:`lemma1_counts`);
* the **round bounds** (:func:`prop1_bound`, :func:`kway_round_bound`,
  :func:`pairwise_lockstep_rounds`) and the one obs recording site for
  them.

Paper mapping
-------------

* **Lemma 1** — rank ``i`` of the stable merge of A and B cuts them at
  the unique ``(j, k)``, ``j + k = i``, with ``A[j-1] <= B[k]`` and
  ``B[k-1] < A[j]``.  Here: :func:`first_condition_holds` /
  :func:`second_condition_violated`; generalised to ``k`` runs the two
  conditions become "runs **before** mine count ties against my
  element, runs **after** count strictly" (:func:`lemma1_counts` — the
  run-index tie-break of the k-way stable order
  ``(value, run, offset)``).
* **Algorithm 1** — the double-ended binary search for ``(j, k)``:
  :func:`co_rank_pairwise` (its four boundary reads per round go
  through the caller's ``read_a`` / ``read_b``, so the same body runs
  on a local array or over collectives).  The k-way form replaces the
  double-ended search with one monotone bisection per run
  (``j_r(i) = |{t : rank(r, t) < i}|``): :func:`co_rank_search`.
* **Proposition 1** — the iteration bound
  ``ceil(log2 min(m, n)) + 1``: :func:`prop1_bound` checks the dynamic
  while-loop count; :func:`kway_round_bound` is the static lock-step
  schedule (``ceil(log2(w + 1)) + 1`` rounds over the ``w + 1``
  candidate cuts).

``Probe`` protocol
------------------

A probe tells the engine how to read its runs; the engine owns the
search semantics.  Required attributes/methods::

    xp             array namespace (jnp on device, np on host)
    width          static max candidate index (run width w)
    lengths        per-run real lengths, broadcastable to the cut shape
    owner_ids      run-id array aligned with counts(): who owns each count
    query_ids      run-id array aligned with counts(): whose query it serves
    owner_lengths  lengths aligned with counts() (the padding clamp)
    init_bounds(i) -> (lo, hi) initial bisection bounds, cut-shaped
    values(t)      candidate run elements at per-run indices t (read())
    counts(x)      -> (count_le, count_lt): per-run occupancy of the
                   candidate values, both Lemma-1 sides
    reduce(cnt)    fold sibling contributions into the cut shape
                   (sum(axis=0) locally, psum + own-row slice on a mesh)
    run_loop(rounds, body, state)  loop runner (fori / Python / psum'd)

``values``/``counts`` are where the tiers differ (local gather vs
``all_gather``+``psum`` vs mmap page faults); the predicate that
combines them is :func:`lemma1_counts`, here, once.
"""

from __future__ import annotations

from typing import Any, Callable, Protocol

import jax.numpy as jnp
from jax import lax

from repro import obs

__all__ = [
    "SIDE_TIES",
    "SIDE_STRICT",
    "counts_ties",
    "count_side",
    "count_below",
    "first_condition_holds",
    "first_condition_violated",
    "second_condition_violated",
    "take_first",
    "kfinger_better",
    "lemma1_counts",
    "value_cut_counts",
    "prop1_bound",
    "kway_round_bound",
    "pairwise_lockstep_rounds",
    "run_fori",
    "run_host",
    "Probe",
    "co_rank_search",
    "co_rank_pairwise",
]


# ---------------------------------------------------------------------------
# §1  Stability: the Lemma-1 predicates and the <= / < tie-break pair.
#
# The stable k-way order is lexicographic on (value, run, offset): ties
# resolve to the earlier run.  Equivalently, when run ``rp`` counts its
# elements against a query element from run ``r``, it counts ties (<=)
# iff rp < r and strictly (<) iff rp > r — Lemma 1's two conditions,
# applied pairwise.  Everything below is a view of that one rule.
# ---------------------------------------------------------------------------

#: ``searchsorted`` sides implementing the pair: an owner run that
#: *precedes* the query's run counts ties (``<=`` -> ``side='right'``);
#: one that *follows* counts strictly (``<`` -> ``side='left'``).
SIDE_TIES = "right"
SIDE_STRICT = "left"


def counts_ties(owner_run: int, query_run: int) -> bool:
    """Does run ``owner_run`` count ties against a query from ``query_run``?

    True iff the owner precedes the query's run in the stable order —
    the run-index tie-break.  Static form, for trace-time-unrolled
    loops (the Pallas kernels).
    """
    return owner_run < query_run


def count_side(owner_run: int, query_run: int) -> str:
    """``searchsorted`` side for run ``owner_run`` counting against
    queries from run ``query_run`` (static run indices)."""
    return SIDE_TIES if counts_ties(owner_run, query_run) else SIDE_STRICT


def count_below(v, x, ties: bool):
    """``v <= x`` (ties) or ``v < x`` (strict) — THE comparison pair.

    This is the only place the ``<=`` / ``<`` asymmetry of Lemma 1 is
    written down; every search, merge decision and count in the repo
    routes through it (or through the ``SIDE_*`` constants, its
    ``searchsorted`` spelling).
    """
    return (v <= x) if ties else (v < x)


def first_condition_holds(a_prev, b_val):
    """Lemma 1, first condition: ``A[j-1] <= B[k]`` (ties to A)."""
    return count_below(a_prev, b_val, ties=True)


def first_condition_violated(a_prev, b_val):
    """``A[j-1] > B[k]`` — j must decrease (Algorithm 1, lines 6-10)."""
    return ~first_condition_holds(a_prev, b_val)


def second_condition_violated(b_prev, a_val):
    """``B[k-1] >= A[j]`` — k must decrease (Algorithm 1, lines 11-15)."""
    return ~count_below(b_prev, a_val, ties=False)


def take_first(first_val, second_val, first_avail, second_avail):
    """Two-finger merge decision: take from the *earlier* input?

    Yes iff it has elements left and (the later input is exhausted or
    ``first <= second``) — ties always emit the earlier input first.
    """
    return first_avail & (
        ~second_avail | count_below(first_val, second_val, ties=True)
    )


def kfinger_better(val, best_val, avail, best_ok):
    """k-finger merge decision: does a *later* run's head beat the best?

    Only strictly (``<``): on ties the earlier run (already in
    ``best``) wins — the run-index tie-break.  Fold runs in index order
    with this and stability is run-index order by construction.
    """
    return avail & (~best_ok | count_below(val, best_val, ties=False))


def lemma1_counts(count_le, count_lt, owner, query, owner_length, xp=jnp):
    """Select each run pair's Lemma-1 side and clamp away padding.

    ``count_le`` / ``count_lt`` are both-side occupancy counts of the
    candidate values in the owner run(s); ``owner`` / ``query`` are
    broadcast-aligned run-id arrays.  Owners before the query's run
    contribute their tie count, owners after their strict count, a run
    contributes nothing to its own queries, and no run ever counts its
    padded tail (the ``owner_length`` clip — valid because padding is
    required to be >= every real element).
    """
    cnt = xp.where(owner < query, count_le, count_lt)
    cnt = xp.where(owner == query, xp.zeros_like(cnt), cnt)
    return xp.minimum(cnt, owner_length)


def value_cut_counts(run, boundary_values, length=None, xp=jnp):
    """Degenerate Lemma-1 search when the boundary *values* are known.

    The cut of a known boundary value ``v`` is the strictly-below count
    (``SIDE_STRICT``): every element equal to ``v`` sorts *after* the
    boundary, so value cuts and rank cuts coincide and the ``O(log w)``
    bisection collapses to one ``searchsorted`` per boundary (the MoE
    segment-cut fast path).  ``length`` clamps away padded tails.
    """
    local = xp.searchsorted(run, boundary_values, side=SIDE_STRICT).astype(
        xp.int32
    )
    if length is not None:
        local = xp.minimum(local, length)
    return local


# ---------------------------------------------------------------------------
# §2  Round bounds (Proposition 1 and its lock-step paddings).
# ---------------------------------------------------------------------------


def prop1_bound(m: int, n: int) -> int:
    """Proposition 1's iteration bound ``ceil(log2 min(m, n)) + 1``.

    Bounds the *dynamic* double-ended search of Algorithm 1; the
    runtime invariant counter (``corank.iterations``) and the property
    tests check recorded iteration counts against this.
    """
    mn = min(m, n)
    if mn <= 0:
        return 0
    return (mn - 1).bit_length() + 1


def kway_round_bound(w: int) -> int:
    """Static lock-step schedule for one run of width ``w``.

    ``ceil(log2(w + 1)) + 1`` rounds: Proposition 1's bound over the
    ``w + 1`` candidate cuts ``0..w``, plus the one convergence round a
    static schedule pays over the dynamic search.  Every tier's k-way
    bisection (device, collective, host planner) runs exactly this many
    rounds.
    """
    return max(1, w).bit_length() + 1


def pairwise_lockstep_rounds(m: int, n: int) -> int:
    """Static schedule for the lock-step pairwise search (Algorithm 1
    run to a fixed count so ``p`` devices' searches can share collective
    rounds): Proposition 1's range is ``min(m, n)`` wide, plus one
    safety round over the per-device dynamic searches."""
    return kway_round_bound(min(m, n)) + 1


# ---------------------------------------------------------------------------
# §3  Loop runners — how the one body executes on each tier.
# ---------------------------------------------------------------------------


def run_fori(rounds: int, body: Callable, state):
    """Device runner: a static ``lax.fori_loop`` (jit/vmap/shard_map
    safe; collective-bearing bodies stay lock-step across the mesh)."""
    return lax.fori_loop(0, rounds, lambda _, s: body(s), state)


def run_host(rounds: int, body: Callable, state):
    """Host runner: a plain Python loop (numpy / mmap probes)."""
    for _ in range(rounds):
        state = body(state)
    return state


# ---------------------------------------------------------------------------
# §4  The k-way lock-step bisection (Algorithm 1 generalised to k runs),
#     probe-parameterized.
# ---------------------------------------------------------------------------


class Probe(Protocol):
    """How a tier reads its runs (see the module docstring table)."""

    xp: Any
    width: int
    lengths: Any
    owner_ids: Any
    query_ids: Any
    owner_lengths: Any

    def init_bounds(self, i):
        ...

    def values(self, t):
        ...

    def counts(self, x):
        ...

    def reduce(self, cnt):
        ...

    def run_loop(self, rounds: int, body: Callable, state):
        ...


def merged_rank(probe: Probe, t):
    """Stable merged rank of candidate elements ``(r, t_r)``.

    ``rank(r, t) = t + sum_{rp != r} |{u : runs[rp][u] (<= | <) runs[r][t]}|``
    with the side chosen by the run-index tie-break — Lemma 1 applied
    pairwise to every sibling run.  The probe supplies the reads; the
    side selection and padding clamp happen here.
    """
    x = probe.values(t)
    count_le, count_lt = probe.counts(x)
    cnt = lemma1_counts(
        count_le,
        count_lt,
        probe.owner_ids,
        probe.query_ids,
        probe.owner_lengths,
        xp=probe.xp,
    )
    return t + probe.reduce(cnt)


def co_rank_search(
    i,
    probe: Probe,
    *,
    metric: str | None = None,
    labels: dict | None = None,
):
    """Cut vector of output rank(s) ``i``: the k-way Lemma-1 bisection.

    One monotone binary search per run, all runs in lock-step:
    ``j_r(i) = |{t : rank(r, t) < i}|`` over the strictly increasing
    :func:`merged_rank`.  ``sum_r j_r(i) == i`` holds exactly because
    the stable rank is a bijection onto ``0..total-1``.  The schedule
    is the static :func:`kway_round_bound` of the probe's width, so the
    loop lowers identically under jit, as collective rounds under
    ``shard_map``, and as a Python loop on host.

    ``i`` must be broadcast-compatible with the probe's cut shape
    (batched callers pass ``i[:, None]``).  ``metric`` names the one
    obs recording site for the round count.
    """
    xp = probe.xp
    rounds = kway_round_bound(probe.width)
    lengths = probe.lengths

    def body(lo_hi):
        lo, hi = lo_hi
        mid = (lo + hi) // 2
        pred = (mid < lengths) & (merged_rank(probe, mid) < i)
        return xp.where(pred, mid + 1, lo), xp.where(pred, hi, mid)

    lo, hi = probe.init_bounds(i)
    lo, _ = probe.run_loop(rounds, body, (lo, hi))
    if metric is not None and obs.enabled():
        obs.gauge(metric, rounds, bound=rounds, **(labels or {}))
    return lo


# ---------------------------------------------------------------------------
# §5  The pairwise Algorithm 1 (double-ended search), read-parameterized.
# ---------------------------------------------------------------------------


def _violations(state, reads, m: int, n: int):
    """Evaluate both Lemma-1 conditions at the current search state
    (four boundary reads; the guards make out-of-range reads moot)."""
    j, k = state[0], state[1]
    a_jm1, b_k, b_km1, a_j = reads(j, k)
    fv = (j > 0) & (k < n) & first_condition_violated(a_jm1, b_k)
    sv = (k > 0) & (j < m) & second_condition_violated(b_km1, a_j)
    return fv, sv


def _algorithm1_step(state, reads, m: int, n: int):
    """One Algorithm-1 refinement step from the four boundary reads.

    Three-way: first condition violated -> decrease ``j`` (lines 6-10);
    else second violated -> decrease ``k`` (lines 11-15); else hold
    (the no-op branch lets converged searches idle inside a lock-step
    schedule).
    """
    j, k, j_low, k_low = state
    fv, sv = _violations(state, reads, m, n)

    delta_j = (j - j_low + 1) // 2  # ceil((j - j_low)/2)
    delta_k = (k - k_low + 1) // 2  # ceil((k - k_low)/2)
    new_k_low = jnp.where(fv, k, k_low)
    new_j_low = jnp.where(fv | ~sv, j_low, j)
    new_j = jnp.where(fv, j - delta_j, jnp.where(sv, j + delta_k, j))
    new_k = jnp.where(fv, k + delta_j, jnp.where(sv, k - delta_k, k))
    return new_j, new_k, new_j_low, new_k_low


def co_rank_pairwise(
    i,
    m: int,
    n: int,
    read_a: Callable,
    read_b: Callable,
    *,
    rounds: int | None = None,
    metric: str | None = None,
    labels: dict | None = None,
):
    """Algorithm 1: co-ranks ``(j, k)`` of output rank ``i``.

    The double-ended binary search, parameterized by how A and B are
    read — ``read_a(idx)`` / ``read_b(idx)`` receive already-clamped
    indices and may be a local gather or a collective remote read.

    ``rounds=None`` runs the dynamic ``lax.while_loop`` and counts
    iterations (Proposition 1 bounds them by :func:`prop1_bound`);
    an integer runs a static lock-step schedule of that many rounds
    (converged searches no-op), which is what collective reads need.

    Returns ``(j, k, iterations)``.  ``metric`` names the one obs
    recording site (histogram of dynamic iterations against the Prop-1
    bound, or gauge of the static round count).
    """
    i = jnp.asarray(i, jnp.int32)

    # Extreme initial assumption — as many of the i elements as possible
    # come from A.  k_low/iters derive from i (``i * 0``) so their
    # shard_map varying-axes types match the loop body's outputs.
    j = jnp.minimum(i, m)
    k = i - j
    j_low = jnp.maximum(i * 0, i - n)
    k_low = i * 0

    # Degenerate sides: Prop. 1's bound is 0 and the extreme initial
    # guess is already the answer — never read the empty array.
    if m == 0 or n == 0:
        if metric is not None and obs.enabled() and rounds is None:
            obs.histogram(
                metric, i * 0, bound=0, m=m, n=n, **(labels or {})
            )
        return j, k, i * 0

    def reads(j, k):
        a_jm1 = read_a(jnp.clip(j - 1, 0, m - 1))
        b_k = read_b(jnp.clip(k, 0, n - 1))
        b_km1 = read_b(jnp.clip(k - 1, 0, n - 1))
        a_j = read_a(jnp.clip(j, 0, m - 1))
        return a_jm1, b_k, b_km1, a_j

    state = (j, k, j_low, k_low)
    if rounds is None:

        def cond(carry):
            fv, sv = _violations(carry[0], reads, m, n)
            return fv | sv

        def body(carry):
            s, iters = carry
            return _algorithm1_step(s, reads, m, n), iters + 1

        state, iters = lax.while_loop(cond, body, (state, i * 0))
    else:
        state = run_fori(
            rounds, lambda s: _algorithm1_step(s, reads, m, n), state
        )
        iters = jnp.full_like(i, rounds)

    j, k = state[0], state[1]
    if metric is not None and obs.enabled():
        if rounds is None:
            obs.histogram(
                metric,
                iters,
                bound=prop1_bound(m, n),
                m=m,
                n=n,
                **(labels or {}),
            )
        else:
            obs.gauge(
                metric,
                rounds,
                bound=rounds,
                prop1_bound=prop1_bound(m, n),
                m=m,
                n=n,
                **(labels or {}),
            )
    return j, k, iters
