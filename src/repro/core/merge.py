"""Stable parallel merge (Algorithm 2 of Siebert & Träff, 2013).

Two implementations of ``C = stable_merge(A, B)``:

* ``merge_partitioned`` — a literal Algorithm 2: the output array is cut into
  ``p`` blocks that differ in size by at most one element; each "processing
  element" (a vmapped lane) co-ranks both endpoints of its block and then
  performs a sequential two-finger stable merge of exactly its input
  segments.  This is the paper-faithful baseline; on TPU the "processing
  element" becomes a Pallas grid cell (see ``repro.kernels.merge``).

* ``merge_by_ranking`` — the fully data-parallel formulation used as the
  fast pure-XLA path: every element's output position is its own rank plus
  its co-rank in the *other* array (``searchsorted`` with the stability
  sides ``left``/``right`` mirroring the ``<=``/``<`` asymmetry of Lemma 1).
  ``O((m+n) log min(m,n))`` comparisons, one scatter, no loop-carried state.

Both are stable: ties emit all A elements (in order) before any B element.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import engine
from repro.core.corank import co_rank_batch
from repro.core.engine import SIDE_STRICT, SIDE_TIES

__all__ = [
    "merge_by_ranking",
    "merge_partitioned",
    "partition_bounds",
    "merge_segment_twofinger",
]


def partition_bounds(total: int, p: int) -> jnp.ndarray:
    """Output block boundaries ``i_r = floor(r * total / p)`` for r=0..p.

    Block sizes differ by at most one element (Proposition 2).  Computed in
    Python integers (shapes are static) so ``r * total`` can never overflow.
    """
    return jnp.asarray([r * total // p for r in range(p + 1)], dtype=jnp.int32)


@jax.jit
def merge_by_ranking(a: jax.Array, b: jax.Array) -> jax.Array:
    """Stable merge via per-element co-ranking (scatter formulation).

    Position of ``a[x]`` in C is ``x + |{y : b[y] < a[x]}|``  (ties: A first,
    so strictly-less — ``side='left'``).  Position of ``b[y]`` is
    ``y + |{x : a[x] <= b[y]}|`` (``side='right'``).  These are exactly the
    co-rank conditions of Lemma 1 applied element-wise.
    """
    m, n = a.shape[0], b.shape[0]
    # Sides from the engine's tie-break: B (the later run) counts
    # strictly against A's elements, A counts ties against B's.
    pos_a = jnp.arange(m, dtype=jnp.int32) + jnp.searchsorted(
        b, a, side=SIDE_STRICT
    ).astype(jnp.int32)
    pos_b = jnp.arange(n, dtype=jnp.int32) + jnp.searchsorted(
        a, b, side=SIDE_TIES
    ).astype(jnp.int32)
    out = jnp.zeros((m + n,), dtype=jnp.result_type(a, b))
    out = out.at[pos_a].set(a, mode="drop", unique_indices=True)
    out = out.at[pos_b].set(b, mode="drop", unique_indices=True)
    return out


def merge_segment_twofinger(
    a: jax.Array,
    b: jax.Array,
    j_lo: jax.Array,
    j_hi: jax.Array,
    k_lo: jax.Array,
    k_hi: jax.Array,
    seg_len: int,
) -> jax.Array:
    """Sequential two-finger stable merge of ``a[j_lo:j_hi]`` and
    ``b[k_lo:k_hi]`` into a fresh array of static length ``seg_len``.

    ``(j_hi - j_lo) + (k_hi - k_lo) <= seg_len``; positions past the real
    output length hold the last merged value (callers slice/mask).  This is
    the per-PE "optimal sequential merge" of Algorithm 2, written with a
    ``fori_loop`` so it vmaps across processing elements.
    """
    m, n = a.shape[0], b.shape[0]
    dtype = jnp.result_type(a, b)

    def step(t, state):
        ja, kb, out = state
        a_val = a[jnp.clip(ja, 0, m - 1)]
        b_val = b[jnp.clip(kb, 0, n - 1)]
        a_avail = ja < j_hi
        b_avail = kb < k_hi
        # Stability: the engine's two-finger rule (on ties take from A).
        take_a = engine.take_first(a_val, b_val, a_avail, b_avail)
        val = jnp.where(take_a, a_val, b_val).astype(dtype)
        valid = a_avail | b_avail
        out = lax.dynamic_update_index_in_dim(
            out, jnp.where(valid, val, out[t]), t, 0
        )
        return ja + jnp.where(take_a, 1, 0), kb + jnp.where(
            take_a & valid, 0, jnp.where(valid, 1, 0)
        ), out

    out = jnp.zeros((seg_len,), dtype=dtype)
    _, _, out = lax.fori_loop(0, seg_len, step, (j_lo, k_lo, out))
    return out


@partial(jax.jit, static_argnames=("p",))
def merge_partitioned(a: jax.Array, b: jax.Array, p: int = 8) -> jax.Array:
    """Algorithm 2: perfectly load-balanced stable parallel merge.

    Each of ``p`` processing elements co-ranks the two endpoints of its
    output block (both, so no synchronisation is needed) and merges exactly
    ``floor/ceil((m+n)/p)`` elements.  Lanes are vmapped, which is the CPU
    stand-in for independent PEs / Pallas grid cells.
    """
    m, n = a.shape[0], b.shape[0]
    total = m + n
    bounds = partition_bounds(total, p)  # (p+1,)
    cr = co_rank_batch(bounds, a, b)
    j, k = cr.j, cr.k  # each (p+1,)

    seg_len = -(-total // p)  # ceil — max block size; blocks differ by <= 1

    def one_pe(j_lo, j_hi, k_lo, k_hi):
        return merge_segment_twofinger(a, b, j_lo, j_hi, k_lo, k_hi, seg_len)

    segs = jax.vmap(one_pe)(j[:-1], j[1:], k[:-1], k[1:])  # (p, seg_len)

    # Scatter the (ragged-by-at-most-one) blocks into the output.
    idx = bounds[:-1, None] + jnp.arange(seg_len)[None, :]  # (p, seg_len)
    valid = idx < bounds[1:, None]
    out = jnp.zeros((total,), dtype=jnp.result_type(a, b))
    out = out.at[jnp.where(valid, idx, total)].set(segs, mode="drop")
    return out
