"""Co-ranking (Algorithm 1 of Siebert & Träff, 2013).

For a stable merge ``C = stable_merge(A, B)`` and an output rank ``i``,
``co_rank`` finds the unique ``(j, k)`` with ``j + k = i`` such that

    (1) j == 0  or  A[j-1] <= B[k]        (first Lemma condition)
    (2) k == 0  or  B[k-1] <  A[j]        (second Lemma condition)

i.e. ``C[0:i] == stable_merge(A[0:j], B[0:k])``.  The search is a
double-ended binary search taking at most ``ceil(log2(min(m, n, i, m+n-i)))``
iterations (Proposition 1) and never materialises the merge.  Stability is
encoded purely in the ``<=`` / ``<`` asymmetry of the two conditions: ties
always resolve to taking the A element first.

This module is the *local-array instantiation* of the one co-rank engine
(``repro.core.engine``): the search body, the Lemma-1 predicates and the
Proposition-1 accounting all live there — here we only supply reads into
two on-device arrays and keep the public API (``co_rank`` /
``co_rank_batch`` / ``CoRankResult`` / ``prop1_bound``).  The dynamic
``lax.while_loop`` runner counts iterations so the Prop-1 invariant stays
observable at runtime; the engine records them (``corank.iterations``).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax

from repro.core import engine
from repro.core.engine import prop1_bound  # noqa: F401  (public re-export)

__all__ = ["co_rank", "co_rank_batch", "CoRankResult", "prop1_bound"]


class CoRankResult(NamedTuple):
    """Result of a co-rank search.

    ``j``/``k`` are the unique co-ranks; ``iterations`` is the number of
    while-loop iterations executed (to validate Proposition 1's bound).
    """

    j: jax.Array
    k: jax.Array
    iterations: jax.Array


@partial(jax.jit, static_argnames=())
def co_rank(i: jax.Array, a: jax.Array, b: jax.Array) -> CoRankResult:
    """Algorithm 1: find co-ranks ``(j, k)`` of output rank ``i``.

    Args:
      i: output rank, ``0 <= i <= m + n`` (scalar, may be traced).
      a: ordered array of shape ``(m,)``.
      b: ordered array of shape ``(n,)``.

    Returns:
      ``CoRankResult(j, k, iterations)`` with ``j + k == i``.
    """
    m = a.shape[0]
    n = b.shape[0]
    j, k, iters = engine.co_rank_pairwise(
        i,
        m,
        n,
        read_a=lambda idx: a[idx],
        read_b=lambda idx: b[idx],
        metric="corank.iterations",
    )
    return CoRankResult(j, k, iters)


def co_rank_batch(i: jax.Array, a: jax.Array, b: jax.Array) -> CoRankResult:
    """Vectorised co-rank for a batch of ranks ``i`` of shape ``(r,)``.

    Used by the partitioned merge (Algorithm 2) to co-rank all partition
    boundaries at once; under ``vmap`` the while loop runs until the slowest
    lane converges, which Proposition 1 bounds by
    ``ceil(log2(min(m, n)))`` iterations.
    """
    return jax.vmap(co_rank, in_axes=(0, None, None))(i, a, b)
