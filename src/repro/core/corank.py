"""Co-ranking (Algorithm 1 of Siebert & Träff, 2013).

For a stable merge ``C = stable_merge(A, B)`` and an output rank ``i``,
``co_rank`` finds the unique ``(j, k)`` with ``j + k = i`` such that

    (1) j == 0  or  A[j-1] <= B[k]        (first Lemma condition)
    (2) k == 0  or  B[k-1] <  A[j]        (second Lemma condition)

i.e. ``C[0:i] == stable_merge(A[0:j], B[0:k])``.  The search is a
double-ended binary search taking at most ``ceil(log2(min(m, n, i, m+n-i)))``
iterations (Proposition 1) and never materialises the merge.  Stability is
encoded purely in the ``<=`` / ``<`` asymmetry of the two conditions: ties
always resolve to taking the A element first.

The implementation is a literal transcription of Algorithm 1 into
``jax.lax.while_loop`` so it can be jitted, vmapped (many ranks at once) and
used under ``shard_map``.  All index arithmetic is int32; array bounds ``m``
and ``n`` are static (taken from the array shapes).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs

__all__ = ["co_rank", "co_rank_batch", "CoRankResult", "prop1_bound"]


def prop1_bound(m: int, n: int) -> int:
    """Proposition 1's iteration bound ``ceil(log2 min(m, n)) + 1``.

    The runtime invariant counter (``corank.iterations``) and the
    property tests both check recorded iteration counts against this.
    """
    mn = min(m, n)
    if mn <= 0:
        return 0
    return (mn - 1).bit_length() + 1


class CoRankResult(NamedTuple):
    """Result of a co-rank search.

    ``j``/``k`` are the unique co-ranks; ``iterations`` is the number of
    while-loop iterations executed (to validate Proposition 1's bound).
    """

    j: jax.Array
    k: jax.Array
    iterations: jax.Array


def _safe_get(arr: jax.Array, idx: jax.Array) -> jax.Array:
    """arr[idx] with idx clamped into range (callers guard validity)."""
    return arr[jnp.clip(idx, 0, arr.shape[0] - 1)]


@partial(jax.jit, static_argnames=())
def co_rank(i: jax.Array, a: jax.Array, b: jax.Array) -> CoRankResult:
    """Algorithm 1: find co-ranks ``(j, k)`` of output rank ``i``.

    Args:
      i: output rank, ``0 <= i <= m + n`` (scalar, may be traced).
      a: ordered array of shape ``(m,)``.
      b: ordered array of shape ``(n,)``.

    Returns:
      ``CoRankResult(j, k, iterations)`` with ``j + k == i``.
    """
    m = a.shape[0]
    n = b.shape[0]
    i = jnp.asarray(i, jnp.int32)

    # Line 1-3: extreme assumption — as many of the i elements as possible
    # come from A.  k_low/iters are derived from i (``i * 0``) so their
    # shard_map varying-axes type matches the loop body's outputs when the
    # search runs per-device inside shard_map.
    j = jnp.minimum(i, m)
    k = i - j
    j_low = jnp.maximum(i * 0, i - n)
    k_low = i * 0

    def first_violated(j, k):
        # j > 0 and k < n and A[j-1] > B[k]
        guard = (j > 0) & (k < n)
        return guard & (_safe_get(a, j - 1) > _safe_get(b, k))

    def second_violated(j, k):
        # k > 0 and j < m and B[k-1] >= A[j]
        guard = (k > 0) & (j < m)
        return guard & (_safe_get(b, k - 1) >= _safe_get(a, j))

    def cond(state):
        j, k, j_low, k_low, iters = state
        return first_violated(j, k) | second_violated(j, k)

    def body(state):
        j, k, j_low, k_low, iters = state
        fv = first_violated(j, k)
        # First Lemma condition violated: decrease j (lines 6-10).
        delta_j = (j - j_low + 1) // 2  # ceil((j - j_low)/2)
        # Second Lemma condition violated: decrease k (lines 11-15).
        delta_k = (k - k_low + 1) // 2  # ceil((k - k_low)/2)

        new_k_low = jnp.where(fv, k, k_low)
        new_j_low = jnp.where(fv, j_low, j)
        new_j = jnp.where(fv, j - delta_j, j + delta_k)
        new_k = jnp.where(fv, k + delta_j, k - delta_k)
        return new_j, new_k, new_j_low, new_k_low, iters + 1

    j, k, _, _, iters = lax.while_loop(
        cond, body, (j, k, j_low, k_low, i * 0)
    )
    if obs.enabled():
        obs.histogram(
            "corank.iterations", iters, bound=prop1_bound(m, n), m=m, n=n
        )
    return CoRankResult(j, k, iters)


def co_rank_batch(i: jax.Array, a: jax.Array, b: jax.Array) -> CoRankResult:
    """Vectorised co-rank for a batch of ranks ``i`` of shape ``(r,)``.

    Used by the partitioned merge (Algorithm 2) to co-rank all partition
    boundaries at once; under ``vmap`` the while loop runs until the slowest
    lane converges, which Proposition 1 bounds by
    ``ceil(log2(min(m, n)))`` iterations.
    """
    return jax.vmap(co_rank, in_axes=(0, None, None))(i, a, b)
