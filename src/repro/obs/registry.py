"""Jit-safe metrics registry: record points that cost nothing when off.

The enable check happens at **trace time** (a plain Python ``if``), so a
disabled record point contributes zero operations to the jaxpr — the
compiled HLO of an instrumented function is identical to the
un-instrumented program, modulo debug metadata (asserted in
``tests/test_obs.py``).  When
enabled, the traced value rides a ``jax.debug.callback`` to the host,
where it is normalised (numpy -> plain Python) and appended to the
active sink as one JSONL-shaped record.

Because enablement is baked in at trace time, toggling it must not let
stale compilations leak: :func:`enable` / :func:`disable` call
``jax.clear_caches()`` whenever the enabled state actually changes.
Swapping *sinks* while staying enabled is free — the baked-in callback
is a trampoline that reads the current sink at call time — which is what
lets ``capture()`` nest cheaply inside an enabled run.

Under ``vmap`` the callback fires once per lane; under ``shard_map``
once per device (pass ``lax.axis_index(axis)`` as a label to tell them
apart — array-valued labels are forwarded through the callback).
"""

from __future__ import annotations

import contextlib
import dataclasses
import logging
import threading
import time

import numpy as np

__all__ = [
    "enable",
    "disable",
    "enabled",
    "capture",
    "record",
    "counter",
    "gauge",
    "histogram",
    "log_event",
    "set_step",
    "flush",
    "totals",
]

_log = logging.getLogger("repro.obs")

# Arrays longer than this are summarised instead of stored verbatim; the
# per-peer vectors the hot paths emit (p, k, E <= a few hundred) stay exact.
_MAX_VERBATIM = 1024


@dataclasses.dataclass
class _ObsState:
    enabled: bool = False
    sink: object | None = None
    step: int | None = None
    lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)


_STATE = _ObsState()


def enabled() -> bool:
    """Trace-time switch every record point checks first."""
    return _STATE.enabled


def enable(metrics_dir: str | None = None, sink=None) -> None:
    """Turn metric emission on.

    ``metrics_dir`` opens a :class:`repro.obs.sink.JsonlSink` there;
    ``sink`` passes an explicit sink (tests).  Exactly one must be given.
    Clears jit caches on the off->on transition so functions traced while
    disabled (callback-free HLO) are re-traced with their record points.
    """
    from repro.obs.sink import JsonlSink

    if (metrics_dir is None) == (sink is None):
        raise ValueError("enable() needs exactly one of metrics_dir / sink")
    if sink is None:
        sink = JsonlSink(metrics_dir)
    with _STATE.lock:
        was_enabled = _STATE.enabled
        old = _STATE.sink
        _STATE.sink = sink
        _STATE.enabled = True
    if old is not None and old is not sink:
        old.close()
    if not was_enabled:
        import jax

        jax.clear_caches()


def disable() -> None:
    """Turn emission off and drop the sink (flushing it first).

    Clears jit caches on the on->off transition: compilations traced
    while enabled carry callback ops and would silently keep emitting
    (into a dead sink) and keep their runtime cost.
    """
    with _STATE.lock:
        was_enabled = _STATE.enabled
        old, _STATE.sink = _STATE.sink, None
        _STATE.enabled = False
        _STATE.step = None
    if old is not None:
        old.close()
    if was_enabled:
        import jax

        jax.clear_caches()


@contextlib.contextmanager
def capture():
    """Collect records in memory for the duration of a ``with`` block.

    Yields the live ``list`` of record dicts.  If obs was already
    enabled, the previous sink is restored (not closed) on exit and no
    cache clearing happens; otherwise this is a scoped enable/disable.
    """
    from repro.obs.sink import ListSink

    sink = ListSink()
    with _STATE.lock:
        was_enabled, prev = _STATE.enabled, _STATE.sink
    if was_enabled:
        with _STATE.lock:
            _STATE.sink = sink
        try:
            yield sink.records
        finally:
            with _STATE.lock:
                _STATE.sink = prev
    else:
        enable(sink=sink)
        try:
            yield sink.records
        finally:
            disable()


def set_step(step: int | None) -> None:
    """Host-side step label stamped on subsequent records."""
    _STATE.step = None if step is None else int(step)


def flush() -> None:
    """Drain the active sink's buffer (launchers call this per step)."""
    import jax

    sink = _STATE.sink
    if sink is not None:
        # effects_barrier guarantees every already-dispatched callback has
        # landed before the buffer is written out.
        jax.effects_barrier()
        sink.flush()


def totals() -> dict[str, float]:
    """Running counter totals accumulated by the active sink."""
    sink = _STATE.sink
    return dict(sink.totals) if sink is not None else {}


# ---------------------------------------------------------------------------
# record points
# ---------------------------------------------------------------------------


def record(name: str, value, *, kind: str = "gauge", **labels) -> None:
    """The one record point: no-op when disabled, callback when enabled.

    ``value`` may be a traced scalar or array.  ``labels`` are attached
    to the record; plain Python values stay host-side, ``jax.Array`` /
    traced values are forwarded through the callback (e.g.
    ``device=lax.axis_index("x")``).
    """
    if not _STATE.enabled:
        return
    import jax
    import jax.numpy as jnp

    static = {}
    traced_keys: list[str] = []
    traced_vals = []
    for k, v in labels.items():
        if isinstance(v, jax.Array) or hasattr(v, "aval"):
            traced_keys.append(k)
            traced_vals.append(v)
        else:
            static[k] = v

    def _cb(v, *tv):
        lbl = dict(static)
        for k, t in zip(traced_keys, tv):
            lbl[k] = _normalise(np.asarray(t))
        _emit(name, kind, np.asarray(v), lbl)

    jax.debug.callback(_cb, jnp.asarray(value), *traced_vals)


def counter(name: str, inc=1, **labels) -> None:
    """Monotonic increment event (sinks accumulate ``totals[name]``)."""
    record(name, inc, kind="counter", **labels)


def gauge(name: str, value, **labels) -> None:
    """Point-in-time value; arrays are stored verbatim (<= 1024 elems)."""
    record(name, value, kind="gauge", **labels)


def histogram(name: str, values, **labels) -> None:
    """Distribution summary: count/min/p50/p90/max/sum of ``values``."""
    record(name, values, kind="histogram", **labels)


def log_event(name: str, **fields) -> None:
    """Host-side (untraced) event: config choices, compile reports.

    Always logged through ``logging.getLogger('repro.obs')``; also lands
    in the sink when metrics are enabled.  Never traced — safe to call
    from dispatch code that runs at trace time.
    """
    fields = {k: _normalise(v) for k, v in fields.items()}
    _log.info("%s %s", name, fields)
    if _STATE.enabled:
        _emit(name, "event", None, fields)


# ---------------------------------------------------------------------------
# host-side normalisation + emission
# ---------------------------------------------------------------------------


def _normalise(v):
    """numpy scalar/array -> plain Python (JSON-serialisable)."""
    if isinstance(v, np.ndarray):
        if v.ndim == 0:
            return v.item()
        return v.tolist()
    if isinstance(v, (np.generic,)):
        return v.item()
    return v


def _summary(arr: np.ndarray) -> dict:
    flat = arr.astype(np.float64).reshape(-1)
    return {
        "count": int(flat.size),
        "min": float(flat.min()),
        "p50": float(np.percentile(flat, 50)),
        "p90": float(np.percentile(flat, 90)),
        "max": float(flat.max()),
        "sum": float(flat.sum()),
    }


def _emit(name: str, kind: str, value, labels: dict) -> None:
    rec: dict = {"ts": time.time(), "metric": name, "kind": kind}
    if _STATE.step is not None:
        rec["step"] = _STATE.step
    if value is not None:
        arr = np.asarray(value)
        if kind == "histogram":
            rec.update(_summary(arr))
        elif arr.ndim > 0 and arr.size > _MAX_VERBATIM:
            rec.update(_summary(arr))
            rec["truncated"] = True
        else:
            rec["value"] = _normalise(arr)
    if labels:
        rec["labels"] = labels
    sink = _STATE.sink
    if sink is not None:
        sink.write(rec)
