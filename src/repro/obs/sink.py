"""Host-side metric sinks: where ``jax.debug.callback`` events land.

A sink receives one plain-``dict`` record per emitted metric event (the
JSONL schema documented in ``repro.obs.__init__``) and must be cheap:
callbacks fire on the runtime's callback thread, so sinks only append /
buffer — summarisation already happened in the registry.

* :class:`ListSink` — in-memory, for tests and ``obs.capture()``.
* :class:`JsonlSink` — append-only ``metrics.jsonl`` under a directory,
  buffered, flushed explicitly (``obs.flush()``; the launchers flush
  once per step) and on close.

Both accumulate ``counter``-kind events into ``totals`` so callers can
read running counts without replaying the event stream.
"""

from __future__ import annotations

import json
import os
import threading
from collections import defaultdict


class Sink:
    """Interface: ``write(record: dict)``, ``flush()``, ``close()``."""

    def __init__(self):
        self.totals: dict[str, float] = defaultdict(float)
        self._lock = threading.Lock()

    def _accumulate(self, record: dict) -> None:
        if record.get("kind") == "counter":
            v = record.get("value", 0)
            try:
                self.totals[record["metric"]] += float(v)
            except TypeError:  # vector counter: sum the components
                self.totals[record["metric"]] += float(sum(v))

    def write(self, record: dict) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()


class ListSink(Sink):
    """Collect records in memory (``obs.capture()`` hands out ``records``)."""

    def __init__(self):
        super().__init__()
        self.records: list[dict] = []

    def write(self, record: dict) -> None:
        with self._lock:
            self.records.append(record)
            self._accumulate(record)


class JsonlSink(Sink):
    """Append JSON lines to ``<directory>/metrics.jsonl``.

    Writes are buffered in memory and serialised under a lock (callback
    threads may interleave); ``flush()`` drains the buffer to disk so a
    crashed run keeps everything up to its last completed step.
    """

    def __init__(self, directory: str, filename: str = "metrics.jsonl"):
        super().__init__()
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, filename)
        self._buf: list[str] = []
        self._fh = open(self.path, "a", encoding="utf-8")

    def write(self, record: dict) -> None:
        line = json.dumps(record, separators=(",", ":"), default=_jsonify)
        with self._lock:
            self._buf.append(line)
            self._accumulate(record)

    def flush(self) -> None:
        with self._lock:
            buf, self._buf = self._buf, []
            if buf and not self._fh.closed:
                self._fh.write("\n".join(buf) + "\n")
                self._fh.flush()

    def close(self) -> None:
        self.flush()
        if not self._fh.closed:
            self._fh.close()


def _jsonify(obj):
    """Fallback serialiser for numpy scalars that escape normalisation."""
    if hasattr(obj, "item"):
        return obj.item()
    if hasattr(obj, "tolist"):
        return obj.tolist()
    return str(obj)
