"""Runtime telemetry: jit-safe metrics, invariant counters, trace spans.

Enable with ``obs.enable(metrics_dir=...)`` (JSONL under that directory)
or ``obs.enable(sink=...)`` / ``obs.capture()`` (in-memory, tests).
While disabled — the default — every record point is a trace-time no-op:
instrumented functions compile to identical HLO, modulo debug metadata
(asserted in ``tests/test_obs.py``), so the hot paths carry their probes
for free.

JSONL schema (one object per line)::

    {"ts": <unix float>, "metric": "<dotted.name>",
     "kind": "counter" | "gauge" | "histogram" | "event",
     "step": <int, when set_step() was called>,
     "value": <scalar or list>            # counter/gauge
     "count"/"min"/"p50"/"p90"/"max"/"sum": ...  # histogram summary
     "labels": {<static + traced labels>}}

Under ``shard_map`` each device emits its own record (instrumented sites
attach ``device=lax.axis_index(axis)`` as a label); under ``vmap`` each
lane does.

Metrics catalog — every record point woven through the hot paths:

== Proposition 1 (co-rank search cost) ==
``corank.iterations``        histogram, per search: actual while-loop
                             iterations of Algorithm 1; labels
                             ``bound = ceil(log2 min(m, n)) + 1`` (the
                             paper's bound; value <= bound always),
                             ``m``, ``n``.
``kway.corank_rounds``       gauge: lock-step binary-search rounds of
                             the k-way cut (static ``ceil(log2 w)+1``);
                             labels ``bound``, ``k``, ``w``.
``splitters.kway_rounds``    gauge: collective rounds of the
                             distributed k-way splitter; labels
                             ``bound``, ``w``, ``device``.
``splitters.pairwise_rounds``gauge: rounds of distributed Algorithm 1;
                             labels ``bound``, ``m``, ``n``.
``splitters.segment_cut_scalars`` counter: int32 scalars gathered by
                             the one-round value-keyed segment cuts
                             (``p * (E+1)``); labels ``n_segments``.

== Proposition 2 (perfect balance) ==
``kway.partition_sizes``     gauge, ``(p,)``: per-PE output block sizes
                             of ``merge_kway`` (differ by <= 1).
``kway.partition_imbalance`` gauge: max - min of the above (0 or 1).
``exchange.block_elements``  gauge: this device's received real
                             elements, ``== N/p`` on the sort path;
                             labels ``device``.

== Exchange traffic ==
``exchange.peer_bytes``      gauge, ``(p,)``: real payload bytes
                             received per source peer (lengths sideband
                             x itemsize); labels ``device``,
                             ``capacity``, ``itemsize``.
``exchange.send_lengths``    gauge, ``(p,)``: elements sent per peer
                             (pre-truncation clip); labels ``device``.
``exchange.padding_slots``   gauge: sentinel-padded slots shipped
                             (``p*capacity - sum(lengths)``) — the
                             static-shape overhead; labels ``device``.
``exchange.length_skew``     gauge: max - min of per-peer segment
                             lengths (raggedness); labels ``device``.

== MoE routing ==
``moe.planned_per_source``   gauge, ``(p,)``: assignments each source
                             planned to send me (from the cut matrix).
``moe.recv_per_source``      gauge, ``(p,)``: assignments that arrived
                             (sideband).
``moe.overflow``             counter: planned - received, summed — the
                             exact per-step drop count (0 at default
                             capacity); labels ``device``.
``moe.group_sizes``          gauge, ``(e_per,)``: rows per owned expert
                             feeding the grouped GEMMs.
``moe.routing_skew``         gauge: max(group_sizes) / mean — 1.0 is
                             perfectly uniform routing.

== External (out-of-core) sort ==
``external.runs_spilled``    counter: sorted runs written to host by
                             the spill phase.
``external.bytes_spilled``   counter: bytes those runs occupy on disk
                             (keys + payload).
``external.windows_merged``  counter: output windows made durable by
                             the streaming k-way merge.
``external.merge_passes``    gauge: fanout-capped passes a sort took
                             (``ceil(log_fanout(n_runs))``).
``external.device_resident_bytes`` gauge: bytes on device right now —
                             one chunk during ``phase="chunk_sort"``,
                             two staged ``(k, window)`` buffers + one
                             output window during ``phase="merge"``
                             (the O(fanout * window) bound
                             ``tests/test_external.py`` asserts).
``external.resident_boundary_elems`` gauge: input elements the host
                             co-rank planner materialises per probe —
                             exactly ``k`` (labels ``bound = k``), the
                             partition-without-merging property.
``external.plan_probes``     counter: boundary probes per cut search
                             (``<= k * (ceil(log2 w) + 1)``).
``external.copy_compute_overlap`` gauge in [0, 1]: fraction of host
                             staging time hidden behind an in-flight
                             device merge (double-buffering quality);
                             labels ``k``.

== Serving (continuous batching) ==
``serve.admitted``           counter: requests moved from the queue
                             into KV-pool slots this step.
``serve.completed``          counter: requests retired this step.
``serve.queue_depth``        gauge: requests waiting for a slot.
``serve.active_slots``       gauge: occupied slots after admission;
                             labels ``capacity`` — the harness asserts
                             value <= capacity on every step.
``serve.slots_recycled``     counter: slot free() calls (recycling is a
                             length reset, never a KV zeroing pass).
``serve.step_latency``       gauge: wall-clock microseconds of one
                             engine step (ragged decode + batched
                             sample, blocking); labels ``batch``.
``serve.topk_merge_rounds``  gauge: merge_kway cuts per batched top-k
                             call — a function of vocab/fanout geometry
                             only, NEVER batch size (the one-merge-
                             per-step claim); labels ``blocks``,
                             ``fanout``.
``serve.topk_candidates``    counter: candidate keys entering the final
                             tournament cut (``batch x runs x k``);
                             labels ``batch``, ``k``.

== Dispatch / compile ==
``kernels.backend_selected`` event, once per (op, backend): which
                             backend ``repro.kernels.ops`` dispatch
                             chose and why (env override vs auto).
``kernels.dispatch_calls``   counter per traced call; labels ``op``,
                             ``backend``.
``hlo.collectives``          event: HLO-predicted collective bytes of a
                             jitted entrypoint (``attach_hlo_report``).
``hlo.report_failed``        event: attach_hlo_report swallowed an
                             exception; labels ``entry``,
                             ``error_type``, ``error``.
``obs.profile_started`` / ``obs.profile_stopped`` events: profiler
                             trace-dump window (``--profile-steps``).

Spans: subsystem boundaries (``sharded_sort``, ``exchange_block``,
``dropless_moe_ffn``, ``merge_kway``, kernel dispatch) sit inside
``obs.span("repro.<name>")`` — ``jax.named_scope`` groups their ops in
profiler views; launcher loops use ``step_span`` / ``host_span``.
"""

from repro.obs.registry import (
    capture,
    counter,
    disable,
    enable,
    enabled,
    flush,
    gauge,
    histogram,
    log_event,
    record,
    set_step,
    totals,
)
from repro.obs.sink import JsonlSink, ListSink, Sink
from repro.obs.trace import (
    attach_hlo_report,
    host_span,
    span,
    start_profile,
    step_span,
    stop_profile,
)

__all__ = [
    "enable",
    "disable",
    "enabled",
    "capture",
    "record",
    "counter",
    "gauge",
    "histogram",
    "log_event",
    "set_step",
    "flush",
    "totals",
    "Sink",
    "ListSink",
    "JsonlSink",
    "span",
    "host_span",
    "step_span",
    "start_profile",
    "stop_profile",
    "attach_hlo_report",
]
