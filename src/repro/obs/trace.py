"""Profiler spans and compile-time reports.

Three span flavours, all no-ops while obs is disabled (so the compiled
program — including its op metadata — is untouched on the off path):

* :func:`span` — for *traced* code: ``jax.named_scope`` so the
  subsystem boundary shows up as a scope prefix on every op it emits,
  which the profiler's HLO-op view groups by.
* :func:`host_span` — for host code: ``jax.profiler.TraceAnnotation``,
  a named region on the host timeline.
* :func:`step_span` — the launcher loop marker:
  ``jax.profiler.StepTraceAnnotation`` so traces viewed in TensorBoard /
  Perfetto get per-step boundaries.

Plus the opt-in trace dump (:func:`start_profile` / :func:`stop_profile`
— ``--profile-steps`` on the launchers) and :func:`attach_hlo_report`,
which parses a jitted entrypoint's compiled HLO with
``repro.launch.hlo_stats`` and logs the predicted collective traffic so
runtime byte counters have a static yardstick to reconcile against.
"""

from __future__ import annotations

import contextlib

from repro.obs.registry import enabled, log_event

__all__ = [
    "span",
    "host_span",
    "step_span",
    "start_profile",
    "stop_profile",
    "attach_hlo_report",
]


@contextlib.contextmanager
def span(name: str):
    """Scope traced ops under ``name`` (``jax.named_scope``) when enabled."""
    if not enabled():
        yield
        return
    import jax

    with jax.named_scope(name):
        yield


@contextlib.contextmanager
def host_span(name: str):
    """Host-timeline annotation (``jax.profiler.TraceAnnotation``)."""
    if not enabled():
        yield
        return
    import jax

    with jax.profiler.TraceAnnotation(name):
        yield


@contextlib.contextmanager
def step_span(name: str, step: int):
    """Per-step profiler marker (``StepTraceAnnotation``) when enabled."""
    if not enabled():
        yield
        return
    import jax

    with jax.profiler.StepTraceAnnotation(name, step_num=step):
        yield


_PROFILING = False


def start_profile(log_dir: str) -> bool:
    """Begin a ``jax.profiler`` trace dump into ``log_dir`` (idempotent)."""
    global _PROFILING
    if _PROFILING:
        return False
    import jax

    jax.profiler.start_trace(log_dir)
    _PROFILING = True
    log_event("obs.profile_started", log_dir=log_dir)
    return True


def stop_profile() -> bool:
    """End the running trace dump, if any."""
    global _PROFILING
    if not _PROFILING:
        return False
    import jax

    jax.profiler.stop_trace()
    _PROFILING = False
    log_event("obs.profile_stopped")
    return True


def attach_hlo_report(name: str, hlo_or_lowered, **labels) -> dict | None:
    """Log the HLO-predicted collective traffic of a jitted entrypoint.

    ``hlo_or_lowered`` is compiled HLO text, or anything with
    ``.compile()`` (a ``jax.stages.Lowered``) or ``.as_text()`` (a
    ``Compiled``).  Returns the stats dict
    ``{total_bytes, per_op_bytes, op_counts}`` from
    ``repro.launch.hlo_stats.collective_bytes`` and emits it as an
    ``hlo.collectives`` event, so runtime per-peer byte counters can be
    reconciled against the compiler's schedule (the acceptance check in
    ``tests/_obs_check.py``).

    A report must never kill the launcher that asked for it: any failure
    (backend refusing to compile for introspection, HLO parse drift, …)
    is logged as an ``hlo.report_failed`` event carrying the exception
    type, and ``None`` is returned.
    """
    from repro.launch.hlo_stats import collective_bytes

    try:
        txt = hlo_or_lowered
        if hasattr(txt, "compile"):
            txt = txt.compile()
        if hasattr(txt, "as_text"):
            txt = txt.as_text()
        stats = collective_bytes(txt)
    except Exception as e:
        log_event(
            "hlo.report_failed",
            entry=name,
            error_type=type(e).__name__,
            error=repr(e),
            **labels,
        )
        return None
    log_event(
        "hlo.collectives",
        entry=name,
        total_bytes=stats["total_bytes"],
        per_op_bytes=stats["per_op_bytes"],
        op_counts=stats["op_counts"],
        **labels,
    )
    return stats
