"""Train step builder: grad accumulation, mixed precision, AdamW, donation.

``build_train_step(cfg)`` returns a function
``(params, opt_state, batch, step) -> (params, opt_state, metrics)`` that
the launcher jits with in/out shardings from the spec trees.  Gradient
accumulation is a ``lax.scan`` over microbatches (activation memory /
``grad_accum``); gradients are accumulated in fp32.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.transformer import train_loss
from repro.train.optimizer import adamw_update, cosine_schedule


def build_train_step(cfg: ModelConfig, *, total_steps: int = 10_000,
                     warmup: int = 200):
    accum = max(cfg.grad_accum, 1)

    def loss_fn(params, batch):
        return train_loss(cfg, params, batch)

    def train_step(params, opt_state, batch, step):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # split the global batch into `accum` microbatches along dim 0
            def micro(tree, i):
                def slice_one(x):
                    mb = x.shape[0] // accum
                    return lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

                return jax.tree.map(slice_one, tree)

            def acc_step(carry, i):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, micro(batch, i))
                g32 = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), grad_acc, g
                )
                return (loss_acc + l, g32), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = lax.scan(
                acc_step, (jnp.float32(0), zero),
                jnp.arange(accum, dtype=jnp.int32),
            )
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        lr = cosine_schedule(
            step, peak_lr=cfg.learning_rate, warmup=warmup, total=total_steps
        )
        params, opt_state, gnorm = adamw_update(
            grads, opt_state, params,
            lr=lr, weight_decay=cfg.weight_decay,
        )
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step
