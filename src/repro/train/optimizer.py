"""AdamW + schedules in pure JAX (no optax dependency).

Moment dtype is configurable (``ModelConfig.adam_dtype``): the 671B config
uses bf16 moments (as DeepSeek-V3 did) to stay inside HBM at 512 chips;
everything else uses fp32.  Update math is always fp32.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params, *, dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
):
    """One AdamW step with global-norm clipping.  ``lr`` may be traced."""
    gsq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    )
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))

    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
            jnp.float32
        )
        p_new = p.astype(jnp.float32) - lr * delta
        return (
            p_new.astype(p.dtype),
            m_new.astype(m.dtype),
            v_new.astype(v.dtype),
        )

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    p_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return p_new, AdamWState(step=step, m=m_new, v=v_new), gnorm


def cosine_schedule(step, *, peak_lr, warmup: int, total: int,
                    floor_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (
        floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
    )
    return jnp.where(s < warmup, warm, cos)
