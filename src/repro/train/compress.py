"""Gradient compression for cross-pod reduction (distributed-optimization
trick, DESIGN.md §5).

int8 block-quantised all-reduce: gradients are scaled per block of 256
values to int8 with stochastic rounding (unbiased), reduced, and dequantised.
Cross-pod DP all-reduce bytes drop 4x (f32) / 2x (bf16); stochastic rounding
keeps E[quantised] = value so SGD/Adam remain unbiased.  Off by default;
enable per-config for bandwidth-constrained inter-pod links (DCN).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    return jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)]), n


def quantize_int8(x: jax.Array, key) -> tuple[jax.Array, jax.Array, int]:
    """Stochastic-rounding int8 block quantisation.

    Returns (q (nb, BLOCK) int8, scales (nb,) f32, original_size)."""
    flat, n = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = blocks / scale
    lo = jnp.floor(y)
    frac = y - lo
    u = jax.random.uniform(key, y.shape)
    q = lo + (u < frac)  # stochastic round: E[q] == y
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale[:, 0], n


def dequantize_int8(q, scales, n, shape, dtype):
    x = q.astype(jnp.float32) * scales[:, None]
    return x.reshape(-1)[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str, key) -> jax.Array:
    """psum with int8 payload: quantise, reduce int32, dequantise.

    Scales are reduced with a max (conservative shared scale) in a tiny
    side psum; payload moves as int8 (4x fewer bytes than f32)."""
    q, scales, n = quantize_int8(x, key)
    # shared scale across the axis so the int8 sum is well-defined
    smax = jax.lax.pmax(scales, axis_name)
    # requantise to the shared scale (cheap, local)
    requant = jnp.clip(
        jnp.round(q.astype(jnp.float32) * (scales / smax)[:, None]),
        -127, 127,
    ).astype(jnp.int8)
    total = jax.lax.psum(requant.astype(jnp.int32), axis_name)
    return dequantize_int8(total, smax, n, x.shape, x.dtype)
