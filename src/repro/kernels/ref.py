"""Pure-jnp oracles for the Pallas kernels (no Pallas imports here)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import SIDE_STRICT, SIDE_TIES

__all__ = ["merge_ref", "merge_np", "sort_ref", "topk_ref"]


def merge_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Stable merge oracle: element-wise co-ranking in pure jnp.

    (The fully engine-independent oracle is ``merge_np`` — numpy's
    stable sort; the tie-break sides here come from the engine.)
    """
    m, n = a.shape[0], b.shape[0]
    pos_a = jnp.arange(m, dtype=jnp.int32) + jnp.searchsorted(
        b, a, side=SIDE_STRICT
    ).astype(jnp.int32)
    pos_b = jnp.arange(n, dtype=jnp.int32) + jnp.searchsorted(
        a, b, side=SIDE_TIES
    ).astype(jnp.int32)
    out = jnp.zeros((m + n,), dtype=jnp.result_type(a, b))
    out = out.at[pos_a].set(a, unique_indices=True)
    out = out.at[pos_b].set(b, unique_indices=True)
    return out


def merge_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """NumPy oracle: stable merge == stable sort of the concatenation."""
    return np.sort(np.concatenate([a, b]), kind="stable")


def sort_ref(x: jax.Array) -> jax.Array:
    return jnp.sort(x, stable=True)


def topk_ref(x: jax.Array, k: int):
    neg = jnp.argsort(-x, stable=True)[:k]
    return x[neg], neg.astype(jnp.int32)
