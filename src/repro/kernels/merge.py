"""Pallas TPU kernel for the co-rank stable merge.

TPU adaptation of the paper (DESIGN.md §3):

* Phase 1 (plain JAX, tiny): co-rank all ``G+1`` tile boundaries with the
  vmapped Algorithm 1 — each output tile of ``S`` elements gets exact input
  windows ``A[j_r : j_{r+1})``, ``B[k_r : k_{r+1})`` with
  ``(j_{r+1}-j_r) + (k_{r+1}-k_r) == S``.  *Perfect* load balance makes the
  Pallas grid uniform and every block shape static — the property that makes
  this algorithm TPU-native (a factor-2-imbalanced partition would force 2x
  tile padding).

* Phase 2 (``pl.pallas_call``): grid cell ``r`` = paper's processing element
  ``r``.  The data-dependent window offsets come in through **scalar
  prefetch** (``pltpu.PrefetchScalarGridSpec``): the BlockSpec ``index_map``
  reads the co-rank boundary array to pick which S-aligned blocks of A and B
  to stage into VMEM.  Each input contributes two consecutive S-blocks so
  the (unaligned) window ``[j_r, j_r + S]`` is always covered.

* The per-cell merge is the paper's co-rank search *re-applied per output
  element, vectorised across VPU lanes*: ``log2`` rounds of a branchless
  binary search (compare + select over the whole tile at once), then one
  gather from each window.  No scalar two-finger loop ever runs.

Everything is validated against ``ref.merge_ref`` in interpret mode
(``tests/test_kernels.py`` sweeps shapes × dtypes × tile sizes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import engine
from repro.core.corank import co_rank_batch
from repro.core.kway import co_rank_kway_batch
from repro.core.mergesort import sentinel_max as _sentinel

__all__ = [
    "merge_pallas",
    "merge_tile_kernel",
    "merge_kway_pallas",
    "merge_kway_tile_kernel",
]

# JAX 0.4.x names it TPUCompilerParams; newer JAX renamed to CompilerParams.
_CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)


def merge_tile_kernel(
    jb_ref,  # (G+1,) scalar-prefetch: A co-rank boundaries
    kb_ref,  # (G+1,) scalar-prefetch: B co-rank boundaries
    a0_ref,  # (1, S) VMEM: A block floor(j_lo/S)
    a1_ref,  # (1, S) VMEM: A block floor(j_lo/S) + 1
    b0_ref,  # (1, S) VMEM
    b1_ref,  # (1, S) VMEM
    c_ref,  # (1, S) VMEM output tile
    *,
    tile: int,
):
    """Merge one output tile: vectorised per-element co-rank search."""
    s = tile
    r = pl.program_id(0)
    j_lo, j_hi = jb_ref[r], jb_ref[r + 1]
    k_lo, k_hi = kb_ref[r], kb_ref[r + 1]
    la = j_hi - j_lo  # elements of A in this tile (la + lb == S)
    lb = k_hi - k_lo
    off_a = j_lo % s  # window offset of j_lo inside the 2S staged block
    off_b = k_lo % s

    a_win = jnp.concatenate([a0_ref[...], a1_ref[...]], axis=1)  # (1, 2S)
    b_win = jnp.concatenate([b0_ref[...], b1_ref[...]], axis=1)

    t = lax.broadcasted_iota(jnp.int32, (1, s), 1)  # local ranks 0..S-1

    # Per-lane binary search for the largest jj with
    #   P(jj) := jj == low_limit  or  A[j_lo + jj - 1] <= B[k_lo + t - jj]
    # (the first Lemma condition; monotone decreasing in jj).  The unique
    # co-rank of local rank t lies in [max(0, t - lb), min(t, la)].
    low = jnp.maximum(jnp.int32(0), t - lb)
    high = jnp.minimum(t, la)

    def p_holds(jj):
        """First Lemma condition at candidate co-rank jj (vector)."""
        a_idx = off_a + jj - 1
        b_idx = off_b + t - jj
        a_prev = jnp.take_along_axis(a_win, jnp.maximum(a_idx, 0), axis=1)
        b_next = jnp.take_along_axis(
            b_win, jnp.clip(b_idx, 0, 2 * s - 1), axis=1
        )
        in_b = (t - jj) < lb  # B[k] exists inside the segment
        le = engine.first_condition_holds(a_prev, b_next)
        # jj == 0 (global j == j_lo + 0 relative start) keeps P true via the
        # low bound; out-of-segment B (k >= lb) also satisfies A[j-1] <= B[k]
        # because the co-rank windows guarantee remaining A fits.
        return jnp.where(in_b, le, True)

    def body(_, lo_hi):
        lo, hi = lo_hi
        mid = (lo + hi + 1) // 2
        pred = p_holds(mid) & (mid > lo)  # mid==lo -> keep lo
        new_lo = jnp.where(pred, mid, lo)
        new_hi = jnp.where(pred, hi, jnp.minimum(hi, mid - 1))
        return new_lo, new_hi

    # ceil(log2(S)) + 1 rounds always suffice for a range of width <= S.
    rounds = engine.kway_round_bound(s - 1)
    jj, _ = lax.fori_loop(0, rounds, body, (low, high))
    kk = t - jj

    # Two-finger decision at (jj, kk): the engine's stability rule —
    # take from A iff A has elements left and (B exhausted or
    # A[jj] <= B[kk]).
    a_val = jnp.take_along_axis(
        a_win, jnp.clip(off_a + jj, 0, 2 * s - 1), axis=1
    )
    b_val = jnp.take_along_axis(
        b_win, jnp.clip(off_b + kk, 0, 2 * s - 1), axis=1
    )
    take_a = engine.take_first(a_val, b_val, jj < la, kk < lb)
    c_ref[...] = jnp.where(take_a, a_val, b_val)


def _pad_to(x: jax.Array, length: int) -> jax.Array:
    pad = length - x.shape[0]
    return jnp.concatenate([x, jnp.full((pad,), _sentinel(x.dtype))])


@functools.partial(
    jax.jit, static_argnames=("tile", "interpret", "dimension_semantics")
)
def merge_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: int = 512,
    interpret: bool = True,
    dimension_semantics: str = "arbitrary",
) -> jax.Array:
    """Stable merge of two ordered 1-D arrays with a Pallas TPU kernel.

    Args:
      a, b: ordered arrays (any length; padded internally to tile multiples
        with order-preserving max sentinels).
      tile: output elements per grid cell (S); must be a multiple of 128 on
        real TPUs for lane alignment.
      interpret: run the kernel body in interpret mode (CPU validation).
      dimension_semantics: 'arbitrary' or 'parallel' for the grid axis —
        tiles are independent (paper's synchronization-freeness), so
        'parallel' is sound; kept switchable for the perf study.
    """
    m, n = a.shape[0], b.shape[0]
    dtype = jnp.result_type(a, b)
    s = tile

    # Logical padding to S-multiples (sentinels merge stably to the tail).
    m2 = -(-max(m, 1) // s) * s
    n2 = -(-max(n, 1) // s) * s
    a_log = _pad_to(a.astype(dtype), m2)
    b_log = _pad_to(b.astype(dtype), n2)
    total = m2 + n2
    g = total // s

    # Phase 1: co-rank the G+1 tile boundaries (the paper's Algorithm 1).
    bounds = jnp.asarray([r * s for r in range(g + 1)], jnp.int32)
    cr = co_rank_batch(bounds, a_log, b_log)
    jb, kb = cr.j, cr.k

    # Physical padding: two extra S-blocks so block q+1 is always in range.
    a_phys = _pad_to(a_log, m2 + 2 * s).reshape(1, -1)
    b_phys = _pad_to(b_log, n2 + 2 * s).reshape(1, -1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, s), lambda r, jb, kb: (0, jb[r] // s)),
            pl.BlockSpec((1, s), lambda r, jb, kb: (0, jb[r] // s + 1)),
            pl.BlockSpec((1, s), lambda r, jb, kb: (0, kb[r] // s)),
            pl.BlockSpec((1, s), lambda r, jb, kb: (0, kb[r] // s + 1)),
        ],
        out_specs=pl.BlockSpec((1, s), lambda r, jb, kb: (0, r)),
    )
    out = pl.pallas_call(
        functools.partial(merge_tile_kernel, tile=s),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, total), dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=(dimension_semantics,),
        ),
    )(jb, kb, a_phys, a_phys, b_phys, b_phys)
    return out[0, : m + n]


# ---------------------------------------------------------------------------
# k-way tile kernel: one co-ranked pass over k sorted runs
# ---------------------------------------------------------------------------


def _lane_count_search(
    win, off, limit, x, ties: bool, s: int, width: int | None = None
):
    """Per-lane count of window-segment elements below each query.

    ``win``: ``(1, width)`` staged buffer (default ``width = 2S``); the
    segment is ``win[off : off + limit]``.  ``x``: ``(1, S)`` per-lane
    queries.  Counts ``<= x`` when ``ties`` else ``< x`` — the engine's
    Lemma-1 comparison pair (``engine.count_below``).  Branchless binary
    search, ``ceil(log2 S)+1`` rounds, all lanes at once.
    """
    width = 2 * s if width is None else width
    lo = jnp.zeros_like(x, jnp.int32)
    hi = jnp.broadcast_to(limit, x.shape).astype(jnp.int32)

    def body(_, lo_hi):
        lo, hi = lo_hi
        mid = (lo + hi) // 2
        v = jnp.take_along_axis(win, jnp.clip(off + mid, 0, width - 1), axis=1)
        pred = engine.count_below(v, x, ties=ties) & (mid < hi)
        return jnp.where(pred, mid + 1, lo), jnp.where(pred, hi, mid)

    rounds = engine.kway_round_bound(s - 1)
    lo, _ = lax.fori_loop(0, rounds, body, (lo, hi))
    return lo


def merge_kway_tile_kernel(
    cb_ref,  # (k, G+1) scalar-prefetch: per-run co-rank boundaries
    *refs,  # 2k key (+ 2k payload) VMEM (1, S) blocks, then the outputs
    k: int,
    tile: int,
    has_vals: bool = False,
):
    """Merge one output tile of the k-way merge.

    The per-lane search generalises the pairwise tile kernel: first the
    tile-local merged rank of every staged input element (k-1 co-rank
    counts per run, vectorised across lanes), then each output lane
    binary-searches those rank vectors for its cut ``j_q(t)`` and takes
    the k-finger minimum with run-index tie-break.  No scalar loop over
    elements ever runs.

    With ``has_vals`` the refs carry a second set of 2k payload blocks
    (same index maps) and a second output tile: the winning run index
    and its cut, already computed for the key decision, select the
    payload — the permutation costs no extra search rounds.
    """
    s = tile
    r = pl.program_id(0)
    n_in = 4 * k if has_vals else 2 * k
    out_ref = refs[n_in]
    t = lax.broadcasted_iota(jnp.int32, (1, s), 1)  # output lanes 0..S-1

    wins, offs, lens = [], [], []
    for q in range(k):
        lo_q, hi_q = cb_ref[q, r], cb_ref[q, r + 1]
        wins.append(
            jnp.concatenate([refs[2 * q][...], refs[2 * q + 1][...]], axis=1)
        )
        offs.append(lo_q % s)
        lens.append(hi_q - lo_q)

    # Tile-local merged rank of element (q, u): u + sum over siblings of
    # the Lemma-1 counts (ties count toward earlier runs).  Ranks of
    # lanes past the segment are forced to S+u: still increasing, never
    # below any output lane t < S.
    u = t  # reuse the iota as per-element index
    ranks = []
    for q in range(k):
        x = jnp.take_along_axis(
            wins[q], jnp.clip(offs[q] + u, 0, 2 * s - 1), axis=1
        )
        cnt = u
        for qp in range(k):
            if qp == q:
                continue
            cnt = cnt + _lane_count_search(
                wins[qp], offs[qp], lens[qp], x,
                ties=engine.counts_ties(qp, q), s=s,
            )
        ranks.append(jnp.where(u < lens[q], cnt, s + u))

    # Output lane t: j_q(t) = |{u : rank_q[u] < t}| via the same per-lane
    # count search on the (sorted) rank vector, then the k-finger decision.
    best_val = best_ok = best_q = None
    jqs = []
    for q in range(k):
        jq = _lane_count_search(
            ranks[q], jnp.int32(0), jnp.int32(s), t, ties=False, s=s, width=s
        )
        val = jnp.take_along_axis(
            wins[q], jnp.clip(offs[q] + jq, 0, 2 * s - 1), axis=1
        )
        avail = jq < lens[q]
        if best_val is None:
            best_val, best_ok = val, avail
            best_q = jnp.zeros_like(t)
        else:
            better = engine.kfinger_better(val, best_val, avail, best_ok)
            best_val = jnp.where(better, val, best_val)
            best_q = jnp.where(better, jnp.int32(q), best_q)
            best_ok = best_ok | avail
        jqs.append(jq)
    out_ref[...] = best_val

    if has_vals:
        out_val_ref = refs[n_in + 1]
        out_v = jnp.zeros(t.shape, out_val_ref.dtype)
        for q in range(k):
            vwin = jnp.concatenate(
                [refs[2 * k + 2 * q][...], refs[2 * k + 2 * q + 1][...]],
                axis=1,
            )
            v = jnp.take_along_axis(
                vwin, jnp.clip(offs[q] + jqs[q], 0, 2 * s - 1), axis=1
            )
            out_v = jnp.where(best_ok & (best_q == q), v, out_v)
        out_val_ref[...] = out_v


@functools.partial(
    jax.jit, static_argnames=("tile", "interpret", "dimension_semantics")
)
def merge_kway_pallas(
    runs: jax.Array,
    vals: jax.Array | None = None,
    *,
    lengths: jax.Array | None = None,
    tile: int = 512,
    interpret: bool = True,
    dimension_semantics: str = "arbitrary",
):
    """Stable merge of ``k`` sorted runs with one Pallas pass.

    Args:
      runs: ``(k, w)`` array, rows sorted ascending (pad ragged runs
        with dtype-max sentinels upstream; sentinels merge to the tail).
      vals: optional ``(k, w)`` payload carried through the merge
        permutation (the external sort's window path); doubles the
        staged blocks, adds no search rounds.
      lengths: optional ``(k,)`` real row lengths.  Rows must stay
        sorted over their full width (sentinel padding).  The tile
        boundaries are then co-ranked against the *real* elements only,
        so padding never interleaves with real dtype-max keys; output
        positions ``>= lengths.sum()`` are unspecified — callers slice.
      tile: output elements per grid cell (S); multiple of 128 on real
        TPUs.
      interpret: run the kernel body in interpret mode (CPU validation).
      dimension_semantics: grid axis annotation; tiles are independent
        so 'parallel' is sound.

    Returns the merged ``(k*w,)`` keys, or ``(keys, vals)`` with a
    payload.

    The k-way generalisation of ``merge_pallas``: phase 1 cuts all
    ``G+1`` tile boundaries into every run at once (multi-way co-rank),
    phase 2 stages two S-blocks per run per tile via scalar-prefetched
    index maps and merges each tile with a vectorised per-lane k-way
    search.  ``log2(k)`` pairwise passes collapse into one.
    """
    k, w = runs.shape
    dtype = runs.dtype
    s = tile

    w2 = -(-max(w, 1) // s) * s
    runs_log = jnp.concatenate(
        [runs, jnp.full((k, w2 - w), _sentinel(dtype), dtype)], axis=1
    )
    total = k * w2
    g = total // s

    # Phase 1: multi-way co-rank of the G+1 tile boundaries (ragged rows
    # clamp at their real lengths, exactly as in core.kway).
    lengths = None if lengths is None else jnp.asarray(lengths, jnp.int32)
    bounds = jnp.asarray([r * s for r in range(g + 1)], jnp.int32)
    cb = co_rank_kway_batch(bounds, runs_log, lengths).T  # (k, G+1)

    # Physical padding: two extra S-blocks per run so block q+1 of the
    # staged window is always in range.
    runs_phys = jnp.concatenate(
        [runs_log, jnp.full((k, 2 * s), _sentinel(dtype), dtype)], axis=1
    )

    def _spec(q: int, plus: int):
        return pl.BlockSpec(
            (1, s), lambda r, cb, q=q, plus=plus: (q, cb[q, r] // s + plus)
        )

    key_specs = [_spec(q, plus) for q in range(k) for plus in (0, 1)]
    if vals is None:
        in_specs = key_specs
        operands = [runs_phys] * (2 * k)
        out_shape = jax.ShapeDtypeStruct((1, total), dtype)
        out_specs = pl.BlockSpec((1, s), lambda r, cb: (0, r))
    else:
        vals_phys = jnp.concatenate(
            [
                vals,
                jnp.zeros((k, w2 - w + 2 * s), vals.dtype),
            ],
            axis=1,
        )
        in_specs = key_specs + [
            _spec(q, plus) for q in range(k) for plus in (0, 1)
        ]
        operands = [runs_phys] * (2 * k) + [vals_phys] * (2 * k)
        out_shape = (
            jax.ShapeDtypeStruct((1, total), dtype),
            jax.ShapeDtypeStruct((1, total), vals.dtype),
        )
        out_specs = (
            pl.BlockSpec((1, s), lambda r, cb: (0, r)),
            pl.BlockSpec((1, s), lambda r, cb: (0, r)),
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    out = pl.pallas_call(
        functools.partial(
            merge_kway_tile_kernel, k=k, tile=s, has_vals=vals is not None
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=(dimension_semantics,),
        ),
    )(cb, *operands)
    if vals is None:
        return out[0, : k * w]
    return out[0][0, : k * w], out[1][0, : k * w]
