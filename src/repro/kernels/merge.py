"""Pallas TPU kernel for the co-rank stable merge.

TPU adaptation of the paper (DESIGN.md §3):

* Phase 1 (plain JAX, tiny): co-rank all ``G+1`` tile boundaries with the
  vmapped Algorithm 1 — each output tile of ``S`` elements gets exact input
  windows ``A[j_r : j_{r+1})``, ``B[k_r : k_{r+1})`` with
  ``(j_{r+1}-j_r) + (k_{r+1}-k_r) == S``.  *Perfect* load balance makes the
  Pallas grid uniform and every block shape static — the property that makes
  this algorithm TPU-native (a factor-2-imbalanced partition would force 2x
  tile padding).

* Phase 2 (``pl.pallas_call``): grid cell ``r`` = paper's processing element
  ``r``.  The data-dependent window offsets come in through **scalar
  prefetch** (``pltpu.PrefetchScalarGridSpec``): the BlockSpec ``index_map``
  reads the co-rank boundary array to pick which S-aligned blocks of A and B
  to stage into VMEM.  Each input contributes two consecutive S-blocks so
  the (unaligned) window ``[j_r, j_r + S]`` is always covered.

* The per-cell merge is the paper's co-rank search *re-applied per output
  element, vectorised across VPU lanes*: ``log2`` rounds of a branchless
  binary search (compare + select over the whole tile at once), then one
  gather from each window.  No scalar two-finger loop ever runs.

Everything is validated against ``ref.merge_ref`` in interpret mode
(``tests/test_kernels.py`` sweeps shapes × dtypes × tile sizes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.corank import co_rank_batch

__all__ = ["merge_pallas", "merge_tile_kernel"]


def _sentinel(dtype) -> jnp.ndarray:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def merge_tile_kernel(
    jb_ref,  # (G+1,) scalar-prefetch: A co-rank boundaries
    kb_ref,  # (G+1,) scalar-prefetch: B co-rank boundaries
    a0_ref,  # (1, S) VMEM: A block floor(j_lo/S)
    a1_ref,  # (1, S) VMEM: A block floor(j_lo/S) + 1
    b0_ref,  # (1, S) VMEM
    b1_ref,  # (1, S) VMEM
    c_ref,  # (1, S) VMEM output tile
    *,
    tile: int,
):
    """Merge one output tile: vectorised per-element co-rank search."""
    s = tile
    r = pl.program_id(0)
    j_lo, j_hi = jb_ref[r], jb_ref[r + 1]
    k_lo, k_hi = kb_ref[r], kb_ref[r + 1]
    la = j_hi - j_lo  # elements of A in this tile (la + lb == S)
    lb = k_hi - k_lo
    off_a = j_lo % s  # window offset of j_lo inside the 2S staged block
    off_b = k_lo % s

    a_win = jnp.concatenate([a0_ref[...], a1_ref[...]], axis=1)  # (1, 2S)
    b_win = jnp.concatenate([b0_ref[...], b1_ref[...]], axis=1)

    t = lax.broadcasted_iota(jnp.int32, (1, s), 1)  # local ranks 0..S-1

    # Per-lane binary search for the largest jj with
    #   P(jj) := jj == low_limit  or  A[j_lo + jj - 1] <= B[k_lo + t - jj]
    # (the first Lemma condition; monotone decreasing in jj).  The unique
    # co-rank of local rank t lies in [max(0, t - lb), min(t, la)].
    low = jnp.maximum(jnp.int32(0), t - lb)
    high = jnp.minimum(t, la)

    def p_holds(jj):
        """First Lemma condition at candidate co-rank jj (vector)."""
        a_idx = off_a + jj - 1
        b_idx = off_b + t - jj
        a_prev = jnp.take_along_axis(a_win, jnp.maximum(a_idx, 0), axis=1)
        b_next = jnp.take_along_axis(
            b_win, jnp.clip(b_idx, 0, 2 * s - 1), axis=1
        )
        in_b = (t - jj) < lb  # B[k] exists inside the segment
        le = a_prev <= b_next
        # jj == 0 (global j == j_lo + 0 relative start) keeps P true via the
        # low bound; out-of-segment B (k >= lb) also satisfies A[j-1] <= B[k]
        # because the co-rank windows guarantee remaining A fits.
        return jnp.where(in_b, le, True)

    def body(_, lo_hi):
        lo, hi = lo_hi
        mid = (lo + hi + 1) // 2
        pred = p_holds(mid) & (mid > lo)  # mid==lo -> keep lo
        new_lo = jnp.where(pred, mid, lo)
        new_hi = jnp.where(pred, hi, jnp.minimum(hi, mid - 1))
        return new_lo, new_hi

    # ceil(log2(S)) + 1 rounds always suffice for a range of width <= S.
    rounds = max(1, (s - 1).bit_length() + 1)
    jj, _ = lax.fori_loop(0, rounds, body, (low, high))
    kk = t - jj

    # Two-finger decision at (jj, kk): take from A iff A has elements left
    # and (B exhausted or A[jj] <= B[kk])  — the stability tie-break.
    a_val = jnp.take_along_axis(
        a_win, jnp.clip(off_a + jj, 0, 2 * s - 1), axis=1
    )
    b_val = jnp.take_along_axis(
        b_win, jnp.clip(off_b + kk, 0, 2 * s - 1), axis=1
    )
    take_a = (jj < la) & ((kk >= lb) | (a_val <= b_val))
    c_ref[...] = jnp.where(take_a, a_val, b_val)


def _pad_to(x: jax.Array, length: int) -> jax.Array:
    pad = length - x.shape[0]
    return jnp.concatenate([x, jnp.full((pad,), _sentinel(x.dtype))])


@functools.partial(
    jax.jit, static_argnames=("tile", "interpret", "dimension_semantics")
)
def merge_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    tile: int = 512,
    interpret: bool = True,
    dimension_semantics: str = "arbitrary",
) -> jax.Array:
    """Stable merge of two ordered 1-D arrays with a Pallas TPU kernel.

    Args:
      a, b: ordered arrays (any length; padded internally to tile multiples
        with order-preserving max sentinels).
      tile: output elements per grid cell (S); must be a multiple of 128 on
        real TPUs for lane alignment.
      interpret: run the kernel body in interpret mode (CPU validation).
      dimension_semantics: 'arbitrary' or 'parallel' for the grid axis —
        tiles are independent (paper's synchronization-freeness), so
        'parallel' is sound; kept switchable for the perf study.
    """
    m, n = a.shape[0], b.shape[0]
    dtype = jnp.result_type(a, b)
    s = tile

    # Logical padding to S-multiples (sentinels merge stably to the tail).
    m2 = -(-max(m, 1) // s) * s
    n2 = -(-max(n, 1) // s) * s
    a_log = _pad_to(a.astype(dtype), m2)
    b_log = _pad_to(b.astype(dtype), n2)
    total = m2 + n2
    g = total // s

    # Phase 1: co-rank the G+1 tile boundaries (the paper's Algorithm 1).
    bounds = jnp.asarray([r * s for r in range(g + 1)], jnp.int32)
    cr = co_rank_batch(bounds, a_log, b_log)
    jb, kb = cr.j, cr.k

    # Physical padding: two extra S-blocks so block q+1 is always in range.
    a_phys = _pad_to(a_log, m2 + 2 * s).reshape(1, -1)
    b_phys = _pad_to(b_log, n2 + 2 * s).reshape(1, -1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, s), lambda r, jb, kb: (0, jb[r] // s)),
            pl.BlockSpec((1, s), lambda r, jb, kb: (0, jb[r] // s + 1)),
            pl.BlockSpec((1, s), lambda r, jb, kb: (0, kb[r] // s)),
            pl.BlockSpec((1, s), lambda r, jb, kb: (0, kb[r] // s + 1)),
        ],
        out_specs=pl.BlockSpec((1, s), lambda r, jb, kb: (0, r)),
    )
    out = pl.pallas_call(
        functools.partial(merge_tile_kernel, tile=s),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, total), dtype),
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(dimension_semantics,),
        ),
    )(jb, kb, a_phys, a_phys, b_phys, b_phys)
    return out[0, : m + n]
