"""Public kernel entry points: dispatch Pallas-on-TPU vs pure-XLA fallback.

Framework code (MoE router, sampler, data pipeline) calls these; the
backend switch keeps the CPU container, interpret-mode validation and real
TPU deployment on one code path.

Dispatch policy (ROADMAP item 1's software half): on a TPU backend the
k-way tile kernel ``merge_kway_pallas`` is preferred automatically; the
``REPRO_MERGE_BACKEND`` env var (``pallas`` | ``xla`` | ``auto``)
overrides the choice fleet-wide without code edits, and requesting the
Pallas path off-TPU falls back to interpret mode — asking for a compiled
Pallas kernel on a non-TPU backend (``interpret=False``) is an error, not
a silent mis-dispatch.  The env var is read at trace time: cached
compilations keyed on ``backend=None`` keep the policy they were traced
under.

The out-of-core path (``repro.external``) merges every output window
through :func:`merge_window`, which resolves its backend through the
same ``_dispatch`` — so ``REPRO_MERGE_BACKEND`` governs the external
merge exactly like the in-memory entry points (``pallas`` routes the
window through the k-way tile kernel with its payload/lengths extension,
interpret-resolved off-TPU rather than hardcoded; ``xla`` /
``xla_native`` take the ranked scatter merge).  Because the driver
passes ``backend=None`` into a jitted entry, the trace-time-read caveat
above applies to external merges too: flip the env var before the first
window, not mid-sort.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import ref
from repro.kernels.merge import merge_kway_pallas, merge_pallas

__all__ = [
    "stable_merge",
    "stable_merge_kway",
    "merge_window",
    "stable_sort",
    "default_backend",
    "BACKEND_ENV_VAR",
    "VALID_BACKENDS",
]

BACKEND_ENV_VAR = "REPRO_MERGE_BACKEND"
VALID_BACKENDS = ("pallas", "xla", "xla_native")

# (op, backend, source) triples already announced — the dispatch choice is
# logged once per distinct selection, not once per traced call.
_LOGGED_CHOICES: set = set()


def default_backend() -> str:
    """'pallas' on TPU, 'xla' elsewhere; ``REPRO_MERGE_BACKEND`` overrides.

    'xla_native' is also accepted: ``stable_sort`` then uses XLA's own
    sort (the escape hatch below); the merge entry points treat it as
    'xla' (they have no native-op equivalent).
    """
    env = os.environ.get(BACKEND_ENV_VAR, "auto").strip().lower()
    if env in VALID_BACKENDS:
        return env
    if env not in ("", "auto"):
        raise ValueError(
            f"{BACKEND_ENV_VAR} must be 'pallas', 'xla', 'xla_native' or "
            f"'auto', got {env!r}"
        )
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _dispatch(op: str, backend: str | None) -> str:
    """Resolve + validate the backend and announce the choice once.

    An explicit ``backend=`` typo must fail loudly, not fall through to
    the XLA path; the selected backend is logged once per (op, backend,
    source) through the obs layer — host-side, so the log itself is
    trace-time only and never enters the compiled program.
    """
    if backend is None:
        resolved = default_backend()
        env = os.environ.get(BACKEND_ENV_VAR, "").strip().lower()
        source = "env" if env in VALID_BACKENDS else "auto"
    else:
        if backend not in VALID_BACKENDS:
            raise ValueError(
                f"{op}: backend must be one of {VALID_BACKENDS}, "
                f"got {backend!r}"
            )
        resolved = backend
        source = "arg"
    key = (op, resolved, source)
    if key not in _LOGGED_CHOICES:
        _LOGGED_CHOICES.add(key)
        obs.log_event(
            "kernels.backend_selected",
            op=op,
            backend=resolved,
            source=source,
            jax_backend=jax.default_backend(),
        )
    if obs.enabled():
        obs.counter("kernels.dispatch_calls", 1, op=op, backend=resolved)
    return resolved


def _resolve_interpret(interpret: bool | None) -> bool:
    """Interpret-mode fallback: off-TPU the Pallas path must interpret."""
    on_tpu = jax.default_backend() == "tpu"
    if interpret is None:
        return not on_tpu
    if not interpret and not on_tpu:
        raise ValueError(
            "pallas backend with interpret=False requires a TPU backend; "
            f"running on {jax.default_backend()!r} — drop interpret=False "
            "or set backend='xla'"
        )
    return interpret


@functools.partial(jax.jit, static_argnames=("backend", "tile", "interpret"))
def stable_merge(
    a: jax.Array,
    b: jax.Array,
    *,
    backend: str | None = None,
    tile: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Stable merge of two ordered 1-D arrays.

    backend: 'pallas' (TPU kernel; interpret-mode on CPU), 'xla'
    (rank-merge via searchsorted — the pure-jnp oracle), or None = auto
    (``default_backend()``: TPU -> pallas, env-overridable).
    """
    backend = _dispatch("stable_merge", backend)
    with obs.span("repro.stable_merge"):
        if backend == "pallas":
            return merge_pallas(
                a, b, tile=tile, interpret=_resolve_interpret(interpret)
            )
        return ref.merge_ref(a, b)


@functools.partial(jax.jit, static_argnames=("backend", "tile", "interpret"))
def stable_merge_kway(
    runs: jax.Array,
    *,
    backend: str | None = None,
    tile: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Stable merge of ``k`` sorted runs (``(k, w)``, rows ascending).

    backend: 'pallas' (one-pass k-way tile kernel — the preferred TPU
    path) or 'xla' (the k-way rank merge from ``repro.core.kway``),
    None = auto.
    """
    from repro.core.kway import merge_kway_ranked

    backend = _dispatch("stable_merge_kway", backend)
    with obs.span("repro.stable_merge_kway"):
        if backend == "pallas":
            return merge_kway_pallas(
                runs, tile=tile, interpret=_resolve_interpret(interpret)
            )
        return merge_kway_ranked(runs)


@functools.partial(
    jax.jit, static_argnames=("backend", "tile", "interpret", "out_len")
)
def merge_window(
    runs: jax.Array,
    vals: jax.Array | None = None,
    lengths: jax.Array | None = None,
    *,
    out_len: int | None = None,
    backend: str | None = None,
    tile: int = 512,
    interpret: bool | None = None,
):
    """Stable ragged k-way merge of one external-sort output window.

    ``runs``: ``(k, w)`` sentinel-padded sorted rows; ``lengths``: real
    row lengths (the co-rank window slices); ``vals``: optional payload
    carried through the permutation.  Returns the first ``out_len``
    merged elements (``k*w`` when unset); with ``lengths``, positions
    ``>= lengths.sum()`` are backend-dependent filler — callers slice to
    the real count.

    backend: 'pallas' (k-way tile kernel with the payload/lengths
    extension; interpret-resolved off-TPU) or 'xla' (ranked scatter
    merge), None = auto — the same ``REPRO_MERGE_BACKEND`` policy as
    every other entry point, so the external path honors the fleet-wide
    override instead of hardcoding a mode.
    """
    from repro.core.kway import merge_kway_ranked

    backend = _dispatch("merge_window", backend)
    k, w = runs.shape
    total = k * w if out_len is None else out_len
    with obs.span("repro.merge_window"):
        if backend == "pallas":
            merged = merge_kway_pallas(
                runs,
                vals,
                lengths=lengths,
                tile=tile,
                interpret=_resolve_interpret(interpret),
            )
            if vals is None:
                return merged[:total]
            return merged[0][:total], merged[1][:total]
        return merge_kway_ranked(runs, vals, lengths, out_len=total)


@functools.partial(jax.jit, static_argnames=("backend",))
def stable_sort(x: jax.Array, *, backend: str | None = None) -> jax.Array:
    """Stable 1-D sort; merge-sort on the co-rank primitive."""
    from repro.core.mergesort import merge_sort

    backend = _dispatch("stable_sort", backend)
    with obs.span("repro.stable_sort"):
        if backend == "xla_native":  # escape hatch: XLA's own sort
            return jnp.sort(x, stable=True)
        return merge_sort(x)
