"""Public kernel entry points: dispatch Pallas-on-TPU vs pure-XLA fallback.

Framework code (MoE router, sampler, data pipeline) calls these; the
backend switch keeps the CPU container, interpret-mode validation and real
TPU deployment on one code path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.merge import merge_kway_pallas, merge_pallas

__all__ = [
    "stable_merge",
    "stable_merge_kway",
    "stable_sort",
    "default_backend",
]


def default_backend() -> str:
    """'pallas' on TPU, 'xla' elsewhere (CPU/GPU containers)."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


@functools.partial(jax.jit, static_argnames=("backend", "tile", "interpret"))
def stable_merge(
    a: jax.Array,
    b: jax.Array,
    *,
    backend: str | None = None,
    tile: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Stable merge of two ordered 1-D arrays.

    backend: 'pallas' (TPU kernel; interpret-mode on CPU), 'xla'
    (rank-merge via searchsorted — the pure-jnp oracle), or None = auto.
    """
    backend = backend or default_backend()
    if backend == "pallas":
        interp = (jax.default_backend() != "tpu") if interpret is None else interpret
        return merge_pallas(a, b, tile=tile, interpret=interp)
    return ref.merge_ref(a, b)


@functools.partial(jax.jit, static_argnames=("backend", "tile", "interpret"))
def stable_merge_kway(
    runs: jax.Array,
    *,
    backend: str | None = None,
    tile: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Stable merge of ``k`` sorted runs (``(k, w)``, rows ascending).

    backend: 'pallas' (one-pass k-way tile kernel) or 'xla' (the k-way
    rank merge from ``repro.core.kway``), None = auto.
    """
    from repro.core.kway import merge_kway_ranked

    backend = backend or default_backend()
    if backend == "pallas":
        interp = (jax.default_backend() != "tpu") if interpret is None else interpret
        return merge_kway_pallas(runs, tile=tile, interpret=interp)
    return merge_kway_ranked(runs)


@functools.partial(jax.jit, static_argnames=("backend",))
def stable_sort(x: jax.Array, *, backend: str | None = None) -> jax.Array:
    """Stable 1-D sort; merge-sort on the co-rank primitive."""
    from repro.core.mergesort import merge_sort

    backend = backend or default_backend()
    if backend == "xla_native":  # escape hatch: XLA's own sort
        return jnp.sort(x, stable=True)
    return merge_sort(x)
