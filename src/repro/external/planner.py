"""Co-rank cut planner: exact window slices over run *boundary probes*.

The paper's central property — co-ranks give the exact input cuts of any
output prefix *without merging* — is what makes external merge passes
cheap: to stream output window ``[lo, hi)`` through the device, the
driver only needs the cut vectors ``J(lo)`` and ``J(hi)``; the window's
inputs are exactly ``runs[r][J(lo)_r : J(hi)_r]`` and they sum to
``hi - lo``.

:func:`co_rank_kway_host` is the *host instantiation* of the one co-rank
engine (``repro.core.engine``): the same lock-step bisection body and the
same run-index tie-break as ``repro.core.kway.co_rank_kway`` — not a
mirror that has to be kept in sync, the literal same code, fed by a
numpy :class:`_HostProbe` over *memory-mapped* runs and run by a plain
Python loop.  Per round it materializes only the ``k`` candidate
boundary elements — the O(k) residency bound the streaming merger
advertises — and issues ``2·k²`` ``searchsorted`` probes, each a binary
search whose element reads fault in single pages of the mmap.  No run
data is ever loaded; the planner's footprint is independent of run
length.

Cost per cut: ``kway_round_bound(w)`` rounds × ``O(k² log w)`` probed
elements — scalars, vs the ``O(total)`` a merge would touch.
"""

from __future__ import annotations

import numpy as np

from repro import obs
from repro.core import engine
from repro.core.engine import SIDE_STRICT, SIDE_TIES

__all__ = ["co_rank_kway_host", "window_ranks"]


class _HostProbe:
    """Engine probe over ``k`` host-resident (typically mmap'd) runs.

    ``values`` touches exactly the ``k`` candidate boundary elements;
    ``counts`` issues ``2k²`` ``np.searchsorted`` probes whose element
    reads fault in single mmap pages.  All index arithmetic is int64
    (runs may exceed int32 rank range on disk).
    """

    xp = np
    run_loop = staticmethod(engine.run_host)

    def __init__(self, runs, lengths: np.ndarray):
        k = len(runs)
        self.runs = runs
        self.width = int(lengths.max()) if k else 0
        self.lengths = lengths  # int64 (k,)
        self.owner_ids = np.arange(k)[:, None]
        self.query_ids = np.arange(k)[None, :]
        self.owner_lengths = lengths[:, None]

    def init_bounds(self, i):
        return np.zeros(len(self.runs), np.int64), self.lengths.copy()

    def values(self, t):
        # The k candidate boundary elements — the only values resident.
        k = len(self.runs)
        x = np.empty(k, dtype=np.asarray(self.runs[0][:0]).dtype)
        for q in range(k):
            x[q] = (
                self.runs[q][min(int(t[q]), int(self.lengths[q]) - 1)]
                if self.lengths[q]
                else 0
            )
        return x

    def counts(self, x):
        le = np.stack(
            [np.searchsorted(r, x, side=SIDE_TIES) for r in self.runs]
        ).astype(np.int64)
        lt = np.stack(
            [np.searchsorted(r, x, side=SIDE_STRICT) for r in self.runs]
        ).astype(np.int64)
        return le, lt

    def reduce(self, cnt):
        return cnt.sum(axis=0)


def co_rank_kway_host(
    i: int,
    runs: list[np.ndarray],
    lengths: np.ndarray | None = None,
) -> np.ndarray:
    """Exact cut vector ``J(i)`` of output rank ``i`` into ``runs``.

    Args:
      i: output rank, clamped to ``[0, sum(lengths)]``.
      runs: ``k`` sorted 1-D array-likes (typically ``np.memmap``); only
        boundary elements are probed, nothing is copied.
      lengths: optional real lengths (defaults to ``len(runs[r])``);
        as in ``co_rank_kway``, rows longer than their real length must
        stay sorted over their full extent (pad with values >= every
        real element) — spilled runs are exact-length, so the default
        always satisfies this.

    Returns:
      int64 ``(k,)`` cuts with ``J.sum() == min(i, total)``; the stable
      k-way merge (run index breaks ties) of ``runs[r][:J_r]`` is
      exactly the first ``i`` merged elements.
    """
    k = len(runs)
    if lengths is None:
        lengths = np.asarray([len(r) for r in runs], np.int64)
    else:
        lengths = np.asarray(lengths, np.int64)
    total = int(lengths.sum())
    i = min(max(int(i), 0), total)
    if k == 0 or i == 0:
        return np.zeros(k, np.int64)

    probe = _HostProbe(runs, lengths)
    lo = engine.co_rank_search(i, probe)

    if obs.enabled():
        # The planner's whole residency: k candidate elements per round
        # (the O(k) bound); searchsorted probes touch pages transiently.
        obs.gauge("external.resident_boundary_elems", k, bound=k)
        obs.counter(
            "external.plan_probes", k * engine.kway_round_bound(probe.width)
        )
    return lo


def window_ranks(total: int, window: int) -> list[tuple[int, int]]:
    """Output-rank intervals ``[lo, hi)`` covering ``[0, total)``."""
    if total <= 0:
        return []
    return [
        (s, min(total, s + window)) for s in range(0, total, window)
    ]
