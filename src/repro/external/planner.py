"""Co-rank cut planner: exact window slices over run *boundary probes*.

The paper's central property — co-ranks give the exact input cuts of any
output prefix *without merging* — is what makes external merge passes
cheap: to stream output window ``[lo, hi)`` through the device, the
driver only needs the cut vectors ``J(lo)`` and ``J(hi)``; the window's
inputs are exactly ``runs[r][J(lo)_r : J(hi)_r]`` and they sum to
``hi - lo``.

:func:`co_rank_kway_host` is the host-side mirror of
``repro.core.kway.co_rank_kway`` (same lock-step binary search, same
"run index breaks ties" Lemma-1 side pair) operating on *memory-mapped*
runs: per round it materializes only the ``k`` candidate boundary
elements — the O(k) residency bound the streaming merger advertises —
and issues ``2·k²`` ``searchsorted`` probes, each a binary search whose
element reads fault in single pages of the mmap.  No run data is ever
loaded; the planner's footprint is independent of run length.

Cost per cut: ``ceil(log2 w)+1`` rounds × ``O(k² log w)`` probed
elements — scalars, vs the ``O(total)`` a merge would touch.
"""

from __future__ import annotations

import numpy as np

from repro import obs

__all__ = ["co_rank_kway_host", "window_ranks"]


def co_rank_kway_host(
    i: int,
    runs: list[np.ndarray],
    lengths: np.ndarray | None = None,
) -> np.ndarray:
    """Exact cut vector ``J(i)`` of output rank ``i`` into ``runs``.

    Args:
      i: output rank, clamped to ``[0, sum(lengths)]``.
      runs: ``k`` sorted 1-D array-likes (typically ``np.memmap``); only
        boundary elements are probed, nothing is copied.
      lengths: optional real lengths (defaults to ``len(runs[r])``);
        as in ``co_rank_kway``, rows longer than their real length must
        stay sorted over their full extent (pad with values >= every
        real element) — spilled runs are exact-length, so the default
        always satisfies this.

    Returns:
      int64 ``(k,)`` cuts with ``J.sum() == min(i, total)``; the stable
      k-way merge (run index breaks ties) of ``runs[r][:J_r]`` is
      exactly the first ``i`` merged elements.
    """
    k = len(runs)
    if lengths is None:
        lengths = np.asarray([len(r) for r in runs], np.int64)
    else:
        lengths = np.asarray(lengths, np.int64)
    total = int(lengths.sum())
    i = min(max(int(i), 0), total)
    lo = np.zeros(k, np.int64)
    if k == 0 or i == 0:
        return lo
    hi = lengths.copy()
    w = int(lengths.max())
    rounds = max(1, w).bit_length() + 1
    rp = np.arange(k)[:, None]
    r = np.arange(k)[None, :]

    for _ in range(rounds):
        mid = (lo + hi) // 2
        # The k candidate boundary elements — the only values resident.
        x = np.empty(k, dtype=np.asarray(runs[0][:0]).dtype)
        for q in range(k):
            x[q] = runs[q][min(int(mid[q]), int(lengths[q]) - 1)] if (
                lengths[q]
            ) else 0
        # merged rank of (r, mid_r): mid_r + Lemma-1 counts into every
        # sibling — ties count toward earlier runs (<= before, < after).
        cr = np.stack(
            [np.searchsorted(runs[q], x, side="right") for q in range(k)]
        ).astype(np.int64)
        cl = np.stack(
            [np.searchsorted(runs[q], x, side="left") for q in range(k)]
        ).astype(np.int64)
        cnt = np.where(rp < r, cr, cl)
        cnt = np.minimum(cnt, lengths[:, None])  # never count padding
        cnt = np.where(rp == r, 0, cnt)
        rank = mid + cnt.sum(axis=0)
        pred = (mid < lengths) & (rank < i)
        lo = np.where(pred, mid + 1, lo)
        hi = np.where(pred, hi, mid)

    if obs.enabled():
        # The planner's whole residency: k candidate elements per round
        # (the O(k) bound); searchsorted probes touch pages transiently.
        obs.gauge("external.resident_boundary_elems", k, bound=k)
        obs.counter("external.plan_probes", k * rounds)
    return lo


def window_ranks(total: int, window: int) -> list[tuple[int, int]]:
    """Output-rank intervals ``[lo, hi)`` covering ``[0, total)``."""
    if total <= 0:
        return []
    return [
        (s, min(total, s + window)) for s in range(0, total, window)
    ]
