"""Public out-of-core sorting API.

``external_sort(keys, vals, *, fanout, window, workdir)`` — stable
spill-to-host sort with the same (key, payload) semantics as
``repro.core.mergesort.sort_key_val``, for inputs larger than one
device-sized chunk.  ``external_argsort`` is the permutation form the
data pipeline's length bucketing uses past
``DataConfig.external_threshold``.
"""

from __future__ import annotations

import numpy as np

from repro.external.merge import DEFAULT_CHUNK, DEFAULT_FANOUT, external_sort

__all__ = ["external_sort", "external_argsort", "DEFAULT_FANOUT",
           "DEFAULT_CHUNK"]


def external_argsort(keys, **kwargs) -> np.ndarray:
    """Stable out-of-core argsort (``np.argsort(kind='stable')``).

    Accepts every :func:`external_sort` keyword; returns the permutation
    as a read-only memory-mapped index array (int32 while it fits, int64
    beyond 2^31 elements).
    """
    n = int(keys.shape[0] if hasattr(keys, "shape") else len(keys))
    idx_dtype = np.int32 if n < (1 << 31) else np.int64
    idx = np.arange(n, dtype=idx_dtype)
    _, order = external_sort(keys, idx, **kwargs)
    return order
