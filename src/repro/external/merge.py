"""External-sort driver: spill sorted runs, co-rank-stream the merge.

Three phases, all resumable from the :class:`~repro.external.runs.RunSet`
manifest:

1. **Spill** — device-sized chunks are stably sorted on-device
   (``sort_key_val`` for pairs, the dispatching ``ops.stable_sort`` for
   bare keys) and written to host as memory-mapped runs.  Chunk order is
   run order, so run-index tie-breaking preserves global stability.
2. **Merge passes** — while more than one run remains, groups of
   ``fanout`` runs are merged into one output run each.  A group merge
   streams *output windows* through the device: the planner's host
   co-rank gives each window its exact ``k`` input slices (probing only
   boundary elements), the slices are staged into a static
   ``(k, window)`` sentinel-padded buffer and merged with
   ``ops.merge_window`` (honoring ``REPRO_MERGE_BACKEND``).  Staging for
   window ``i+1`` is issued while window ``i``'s merge is still in
   flight — double-buffered host→device copies — and the device never
   holds more than two staged windows plus one output window:
   O(fanout · window) elements, regardless of input size.
3. **Publish** — the last surviving run is the sorted output; its
   memory-mapped arrays are returned without materializing them.

Fanout caps the per-pass device tail: a pass stages at most
``2 · fanout · window`` input elements, so any run count is handled by
``ceil(log_fanout(n_runs))`` passes instead of one wide merge that
wouldn't fit.
"""

from __future__ import annotations

import hashlib
import math
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.mergesort import sentinel_max, sort_key_val_jit
from repro.external import planner
from repro.external.runs import Run, RunSet, spill_run
from repro.kernels import ops

__all__ = ["external_sort", "DEFAULT_FANOUT", "DEFAULT_CHUNK"]

# Runs merged per pass.  8 keeps a pass's staged tail (2·fanout·window
# elements) comfortably under one chunk at the default window while
# needing only log8 passes; callers tune it per device-memory budget.
DEFAULT_FANOUT = 8
DEFAULT_CHUNK = 1 << 18


def _np_sentinel(dtype) -> np.generic:
    return np.asarray(sentinel_max(np.dtype(dtype)))


def _fingerprint(keys, n: int) -> str:
    """Cheap input identity for resume safety: strided sample digest."""
    if n == 0:
        return "empty"
    stride = max(1, n // 64)
    sample = np.ascontiguousarray(np.asarray(keys[::stride][:65]))
    return hashlib.sha1(sample.tobytes()).hexdigest()[:16]


def external_sort(
    keys,
    vals=None,
    *,
    chunk: int = DEFAULT_CHUNK,
    fanout: int = 0,
    window: int = 0,
    workdir: str,
    backend: str | None = None,
    resume: bool = True,
    cleanup: bool = True,
    on_window=None,
):
    """Stable out-of-core sort of ``keys`` (and a payload) by spill+merge.

    Args:
      keys: 1-D array-like, sliced chunk-by-chunk (an ``np.memmap`` works;
        the whole input is never copied at once).
      vals: optional same-length payload carried through the stable
        permutation (``np.argsort(kind='stable')`` semantics).
      chunk: elements sorted on-device per spill — the device-memory
        proxy; at most one chunk is resident during phase 1.
      fanout: runs merged per pass (>= 2; 0 = ``DEFAULT_FANOUT``).
      window: output elements streamed per merge step (0 = ``chunk //
        fanout``, which caps merge-phase residency at about one chunk).
      workdir: spill directory; created if missing.  Holds the run files
        and the crash-resume manifest.
      backend: merge backend override forwarded to ``ops.merge_window``
        (None = auto / ``REPRO_MERGE_BACKEND``).
      resume: pick up a matching interrupted sort from ``workdir``'s
        manifest instead of restarting (mismatched input or parameters
        always restart).
      cleanup: delete intermediate runs once sorted (the final output
        files always remain — they back the returned arrays).
      on_window: optional ``f(out_pass, group, window_idx)`` progress
        hook, called after each window is durable (tests use it to
        inject crashes).

    Returns:
      The sorted keys as a read-only memory-mapped array, or ``(keys,
      vals)`` when a payload was given.
    """
    n = int(keys.shape[0] if hasattr(keys, "shape") else len(keys))
    if vals is not None:
        vn = int(vals.shape[0] if hasattr(vals, "shape") else len(vals))
        if vn != n:
            raise ValueError(f"keys/vals length mismatch: {n} vs {vn}")
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    fanout = fanout or DEFAULT_FANOUT
    if fanout < 2:
        raise ValueError(f"fanout must be >= 2 (or 0 for default), got {fanout}")
    window = window or max(1, chunk // fanout)

    meta = {
        "n": n,
        "chunk": int(chunk),
        "window": int(window),
        "fanout": int(fanout),
        "key_dtype": str(np.asarray(keys[:0]).dtype),
        "val_dtype": None if vals is None else str(np.asarray(vals[:0]).dtype),
        "fingerprint": _fingerprint(keys, n),
    }

    os.makedirs(workdir, exist_ok=True)
    rs = RunSet.load(workdir) if resume else None
    if rs is not None and not rs.matches(meta):
        rs = None  # different input/parameters: stale state, restart
    if rs is None:
        rs = RunSet(workdir, meta)
        rs.save()

    with obs.host_span("repro.external_sort"):
        if rs.done is None:
            _spill_phase(keys, vals, rs, chunk=chunk, backend=backend)
            final = _merge_phases(
                rs,
                fanout=fanout,
                window=window,
                backend=backend,
                on_window=on_window,
            )
        else:
            final = rs.done

    if cleanup:
        keep = {final.key_path, final.val_path}
        for path in rs.run_files() - keep:
            if path and os.path.exists(path):
                os.remove(path)

    if vals is None:
        return final.keys()
    return final.keys(), final.vals()


# ---------------------------------------------------------------------------
# phase 1: chunk sort + spill
# ---------------------------------------------------------------------------


def _spill_phase(keys, vals, rs: RunSet, *, chunk: int, backend) -> None:
    n = rs.meta["n"]
    n_chunks = max(1, math.ceil(n / chunk))  # n == 0 spills one empty run
    for ci in range(rs.chunks_done, n_chunks):
        lo, hi = ci * chunk, min(n, (ci + 1) * chunk)
        k_host = np.asarray(keys[lo:hi])
        if vals is not None:
            v_host = np.asarray(vals[lo:hi])
        if obs.enabled():
            resident = k_host.nbytes + (
                v_host.nbytes if vals is not None else 0
            )
            obs.gauge(
                "external.device_resident_bytes", resident,
                phase="chunk_sort",
            )
        if hi > lo:
            if vals is None:
                k_np = np.asarray(ops.stable_sort(
                    jnp.asarray(k_host), backend=backend
                ))
                v_np = None
            else:
                sk, sv = sort_key_val_jit(
                    jnp.asarray(k_host), jnp.asarray(v_host)
                )
                k_np, v_np = np.asarray(sk), np.asarray(sv)
        else:
            k_np = k_host
            v_np = None if vals is None else v_host
        run = spill_run(rs.workdir, f"run_p0_c{ci:05d}", k_np, v_np)
        rs.add_chunk_run(run)  # saves the manifest


# ---------------------------------------------------------------------------
# phase 2: multi-pass co-rank-streamed k-way merge
# ---------------------------------------------------------------------------


def _merge_phases(
    rs: RunSet, *, fanout: int, window: int, backend, on_window
) -> Run:
    p = 0
    while True:
        cur = rs.level_runs(p)
        if len(cur) == 1:
            rs.done = cur[0]
            rs.save()
            if obs.enabled():
                obs.gauge("external.merge_passes", p)
            return cur[0]
        groups = [cur[i : i + fanout] for i in range(0, len(cur), fanout)]
        outs = rs.level_runs(p + 1)
        for gi in range(len(outs), len(groups)):
            group = groups[gi]
            if len(group) == 1:
                out = group[0]  # odd tail rides through unchanged
            else:
                out = _merge_group(
                    rs, p + 1, gi, group,
                    window=window, backend=backend, on_window=on_window,
                )
            rs.complete_group(p + 1, out)  # saves the manifest
        p += 1


def _merge_group(
    rs: RunSet,
    out_pass: int,
    gi: int,
    group: list[Run],
    *,
    window: int,
    backend,
    on_window,
) -> Run:
    k = len(group)
    key_views = [r.keys() for r in group]
    has_vals = group[0].val_path is not None
    val_views = [r.vals() for r in group] if has_vals else None
    lengths = np.asarray([r.length for r in group], np.int64)
    total = int(lengths.sum())
    key_dtype = np.dtype(group[0].key_dtype)
    val_dtype = np.dtype(group[0].val_dtype) if has_vals else None
    sentinel = _np_sentinel(key_dtype)

    name = f"run_p{out_pass}_g{gi:05d}"
    out_key = os.path.join(rs.workdir, name + ".keys.npy")
    out_val = os.path.join(rs.workdir, name + ".vals.npy")
    tmp_key, tmp_val = out_key + ".part.npy", out_val + ".part.npy"

    # Resume bookkeeping: a matching in-progress merge restarts at its
    # recorded window; anything else restarts the group from scratch.
    state = rs.merge
    if not (
        state
        and state.get("out_pass") == out_pass
        and state.get("group") == gi
        and state.get("length") == total
        and os.path.exists(tmp_key)
        and (not has_vals or os.path.exists(tmp_val))
    ):
        state = {
            "out_pass": out_pass,
            "group": gi,
            "windows_done": 0,
            "length": total,
        }
        for path in (tmp_key, tmp_val):
            if os.path.exists(path):
                os.remove(path)

    def _open_out(path, dtype):
        mode = "r+" if os.path.exists(path) else "w+"
        m = np.lib.format.open_memmap(
            path, mode=mode, dtype=dtype, shape=(max(total, 1),)
        )
        return m

    out_k = _open_out(tmp_key, key_dtype)
    out_v = _open_out(tmp_val, val_dtype) if has_vals else None

    n_windows = math.ceil(total / window) if total else 0
    start_w = min(int(state["windows_done"]), n_windows)
    cut_lo = planner.co_rank_kway_host(start_w * window, key_views, lengths)

    t_wait = 0.0  # blocked on device results
    t_overlap = 0.0  # staging time hidden behind an in-flight merge

    def _stage(wi: int, lo_cuts: np.ndarray):
        """Slice window ``wi``'s inputs and start the host→device copy."""
        end = min(total, (wi + 1) * window)
        hi_cuts = planner.co_rank_kway_host(end, key_views, lengths)
        seg = (hi_cuts - lo_cuts).astype(np.int64)
        kbuf = np.full((k, window), sentinel, key_dtype)
        vbuf = np.zeros((k, window), val_dtype) if has_vals else None
        for q in range(k):
            if seg[q]:
                kbuf[q, : seg[q]] = key_views[q][lo_cuts[q] : hi_cuts[q]]
                if has_vals:
                    vbuf[q, : seg[q]] = val_views[q][lo_cuts[q] : hi_cuts[q]]
        dev = (
            jax.device_put(kbuf),
            jax.device_put(vbuf) if has_vals else None,
            jax.device_put(seg.astype(np.int32)),
        )
        return {"wi": wi, "end": end, "hi_cuts": hi_cuts, "dev": dev}

    staged = _stage(start_w, cut_lo) if start_w < n_windows else None
    for wi in range(start_w, n_windows):
        cur = staged
        dk, dv, dl = cur["dev"]
        merged = ops.merge_window(
            dk, dv, dl, out_len=window, backend=backend
        )  # dispatched async; staging below overlaps it
        t0 = time.perf_counter()
        staged = (
            _stage(wi + 1, cur["hi_cuts"]) if wi + 1 < n_windows else None
        )
        t_overlap += time.perf_counter() - t0
        if obs.enabled():
            mk = merged[0] if has_vals else merged
            resident = dk.nbytes + dl.nbytes + mk.nbytes
            if has_vals:
                resident += dv.nbytes + merged[1].nbytes
            if staged is not None:
                sk, sv, sl = staged["dev"]
                resident += sk.nbytes + sl.nbytes
                if has_vals:
                    resident += sv.nbytes
            obs.gauge(
                "external.device_resident_bytes", resident, phase="merge",
                k=k,
            )
        t0 = time.perf_counter()
        if has_vals:
            mk_host, mv_host = np.asarray(merged[0]), np.asarray(merged[1])
        else:
            mk_host = np.asarray(merged)
        t_wait += time.perf_counter() - t0

        lo_rank = wi * window
        count = cur["end"] - lo_rank
        out_k[lo_rank : cur["end"]] = mk_host[:count]
        out_k.flush()
        if has_vals:
            out_v[lo_rank : cur["end"]] = mv_host[:count]
            out_v.flush()
        # Data is durable before the manifest advances: a crash here
        # re-merges (idempotently) at most this window.
        state["windows_done"] = wi + 1
        rs.merge = state
        rs.save()
        if obs.enabled():
            obs.counter("external.windows_merged", 1)
        if on_window is not None:
            on_window(out_pass, gi, wi)
        cut_lo = cur["hi_cuts"]

    if obs.enabled():
        denom = t_overlap + t_wait
        obs.gauge(
            "external.copy_compute_overlap",
            (t_overlap / denom) if denom > 0 else 0.0,
            k=k,
        )

    del out_k, out_v  # flush + close before publishing
    os.replace(tmp_key, out_key)
    if has_vals:
        os.replace(tmp_val, out_val)
    return Run(
        key_path=out_key,
        length=total,
        key_dtype=str(key_dtype),
        val_path=out_val if has_vals else None,
        val_dtype=str(val_dtype) if has_vals else None,
    )
