"""Sorted-run spill segments and the crash-resumable ``RunSet`` manifest.

A *run* is one sorted (key, payload) segment spilled to host storage as
memory-mapped ``.npy`` files — the standard numpy header is the "small
header" (dtype, length) and ``np.load(mmap_mode='r')`` reopens a segment
without reading it.  Spills are atomic (write to a ``.tmp`` sibling,
``os.replace``), so a crash mid-spill never leaves a half-run that looks
valid.

The :class:`RunSet` manifest (``runset.json``, also written atomically)
records everything the multi-pass merge needs to resume after a crash:

* ``meta`` — the sort parameters and an input fingerprint; a resume with
  different input or parameters discards the stale state.
* ``chunks_done`` — how many device-sized chunks were sorted + spilled
  (phase 1 restarts after the last complete chunk).
* ``passes`` — the completed runs of every merge level; level 0 is the
  spilled chunks, level ``p+1`` holds the outputs of merging level
  ``p`` in groups of ``fanout``.
* ``merge`` — the in-progress group merge: output level/group, the
  partially-written output segment and how many output windows of it
  are already complete.  Window writes are idempotent (the co-rank plan
  makes window ``i``'s content a pure function of the inputs), so the
  manifest only needs to be durably *behind* the data: windows are
  flushed before ``windows_done`` advances, and a torn window is simply
  re-merged.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro import obs

__all__ = ["Run", "RunSet", "spill_run", "MANIFEST_NAME"]

MANIFEST_NAME = "runset.json"


@dataclasses.dataclass(frozen=True)
class Run:
    """One sorted spill segment: mmap-openable key (and payload) files."""

    key_path: str
    length: int
    key_dtype: str
    val_path: str | None = None
    val_dtype: str | None = None

    def keys(self) -> np.ndarray:
        """Memory-mapped (read-only) key segment; reads fault pages in."""
        return np.load(self.key_path, mmap_mode="r")

    def vals(self) -> np.ndarray | None:
        if self.val_path is None:
            return None
        return np.load(self.val_path, mmap_mode="r")

    @property
    def nbytes(self) -> int:
        n = self.length * np.dtype(self.key_dtype).itemsize
        if self.val_path is not None:
            n += self.length * np.dtype(self.val_dtype).itemsize
        return n

    def to_json(self, workdir: str) -> dict:
        rel = lambda p: None if p is None else os.path.relpath(p, workdir)
        return {
            "key_path": rel(self.key_path),
            "length": self.length,
            "key_dtype": self.key_dtype,
            "val_path": rel(self.val_path),
            "val_dtype": self.val_dtype,
        }

    @staticmethod
    def from_json(d: dict, workdir: str) -> "Run":
        absp = lambda p: None if p is None else os.path.join(workdir, p)
        return Run(
            key_path=absp(d["key_path"]),
            length=int(d["length"]),
            key_dtype=d["key_dtype"],
            val_path=absp(d["val_path"]),
            val_dtype=d["val_dtype"],
        )


def _atomic_save(path: str, arr: np.ndarray) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.save(f, arr)
    os.replace(tmp, path)


def spill_run(
    workdir: str,
    name: str,
    keys: np.ndarray,
    vals: np.ndarray | None = None,
) -> Run:
    """Atomically write one sorted run; returns its :class:`Run` handle."""
    key_path = os.path.join(workdir, name + ".keys.npy")
    _atomic_save(key_path, keys)
    val_path = val_dtype = None
    if vals is not None:
        val_path = os.path.join(workdir, name + ".vals.npy")
        _atomic_save(val_path, vals)
        val_dtype = str(vals.dtype)
    run = Run(
        key_path=key_path,
        length=int(keys.shape[0]),
        key_dtype=str(keys.dtype),
        val_path=val_path,
        val_dtype=val_dtype,
    )
    if obs.enabled():
        obs.counter("external.runs_spilled", 1)
        obs.counter("external.bytes_spilled", run.nbytes)
    return run


class RunSet:
    """Manifest-backed state of one external sort inside ``workdir``."""

    def __init__(self, workdir: str, meta: dict):
        self.workdir = workdir
        self.meta = dict(meta)
        self.chunks_done: int = 0
        self.passes: dict[int, list[Run]] = {0: []}
        # In-progress group merge: {"out_pass", "group", "windows_done",
        # "out_name", "length"} or None.
        self.merge: dict | None = None
        self.done: Run | None = None

    # -- persistence --------------------------------------------------------

    @property
    def manifest_path(self) -> str:
        return os.path.join(self.workdir, MANIFEST_NAME)

    def save(self) -> None:
        state = {
            "version": 1,
            "meta": self.meta,
            "chunks_done": self.chunks_done,
            "passes": {
                str(p): [r.to_json(self.workdir) for r in rs]
                for p, rs in self.passes.items()
            },
            "merge": self.merge,
            "done": None if self.done is None else self.done.to_json(
                self.workdir
            ),
        }
        tmp = self.manifest_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f, indent=1, sort_keys=True)
        os.replace(tmp, self.manifest_path)

    @classmethod
    def load(cls, workdir: str) -> "RunSet | None":
        path = os.path.join(workdir, MANIFEST_NAME)
        if not os.path.exists(path):
            return None
        try:
            with open(path) as f:
                state = json.load(f)
        except (OSError, ValueError):
            return None  # torn manifest: treat as absent, restart
        rs = cls(workdir, state.get("meta", {}))
        rs.chunks_done = int(state.get("chunks_done", 0))
        rs.passes = {
            int(p): [Run.from_json(d, workdir) for d in runs]
            for p, runs in state.get("passes", {"0": []}).items()
        }
        rs.merge = state.get("merge")
        done = state.get("done")
        rs.done = None if done is None else Run.from_json(done, workdir)
        return rs

    def matches(self, meta: dict) -> bool:
        """True iff the stored state belongs to this exact sort call."""
        return self.meta == meta

    # -- merge-state helpers -------------------------------------------------

    def level_runs(self, p: int) -> list[Run]:
        return self.passes.setdefault(p, [])

    def add_chunk_run(self, run: Run) -> None:
        self.passes.setdefault(0, []).append(run)
        self.chunks_done += 1
        self.save()

    def complete_group(self, out_pass: int, run: Run) -> None:
        self.passes.setdefault(out_pass, []).append(run)
        self.merge = None
        self.save()

    def run_files(self) -> set[str]:
        out: set[str] = set()
        for rs in self.passes.values():
            for r in rs:
                out.add(r.key_path)
                if r.val_path:
                    out.add(r.val_path)
        return out
