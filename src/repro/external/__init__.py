"""Out-of-core external sort: spill-to-host runs, co-rank-streamed merge.

The dataset-scale tier of the paper's merge machinery (ROADMAP
"larger-than-memory sort"): inputs that do not fit on-device are sorted
as device-sized chunks, spilled to host as memory-mapped sorted runs,
and k-way merged back through the device window by window.  The paper's
partition-without-merging property is what makes the streaming cheap —
the exact input cuts of any output window come from a co-rank search
over run *boundary probes* (O(k) elements resident), never from
materializing run data.

Public surface: :mod:`repro.external.api` (``external_sort``,
``external_argsort``); the pieces underneath are
:mod:`repro.external.runs` (spill segments + the crash-resumable
``RunSet`` manifest), :mod:`repro.external.planner` (host-side exact
co-rank cut planner over memory-mapped runs) and
:mod:`repro.external.merge` (the spill / multi-pass merge driver).
"""

from repro.external.api import (
    DEFAULT_FANOUT,
    external_argsort,
    external_sort,
)
from repro.external.planner import co_rank_kway_host
from repro.external.runs import Run, RunSet, spill_run

__all__ = [
    "external_sort",
    "external_argsort",
    "DEFAULT_FANOUT",
    "co_rank_kway_host",
    "Run",
    "RunSet",
    "spill_run",
]
