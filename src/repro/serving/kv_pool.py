"""Fixed-capacity KV/state slot pool for the continuous-batching engine.

The pool owns one model :class:`~repro.models.transformer.Cache` whose
batch dimension is the slot axis (``capacity`` slots) and whose
``length`` is a per-slot ``(capacity,)`` vector — the ragged decode path
(``decode_step_ragged``) writes slot ``s``'s next token at position
``length[s]`` and masks its attention at ``length[s] + 1``.

Slots are recycled, not reallocated: freeing a slot only returns it to
the free list and resets its length to zero.  The stale KV bytes left
behind are *provably* unreadable — every attention read is masked by the
slot's own length, which restarts at 0 on reuse — so recycling costs one
int32 store, no cache zeroing.  ``tests/test_serving.py`` pins that
isolation property (a recycled slot's token stream is byte-identical to
the same request decoded in a fresh pool).

Allocation order is LIFO over the free list (cheap, and irrelevant to
results — slot identity never influences tokens); admission *fairness*
is the scheduler's job, not the pool's.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro import obs
from repro.configs.base import ModelConfig
from repro.models.transformer import Cache, init_cache

__all__ = ["KVPool"]


class KVPool:
    """``capacity`` recyclable decode slots over one shared cache.

    Host-side free-list bookkeeping plus the device-side cache pytree;
    the engine reads/writes ``pool.cache`` around each decode step and
    calls :meth:`alloc` / :meth:`free` as requests come and go.
    """

    def __init__(self, cfg: ModelConfig, capacity: int, max_len: int,
                 dtype=jnp.bfloat16):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        base = init_cache(cfg, capacity, max_len)
        if base.kind != "gqa":
            raise NotImplementedError(
                f"KVPool supports the 'gqa' cache family; got {base.kind!r}"
            )
        self.capacity = capacity
        self.max_len = max_len
        self.cache = Cache(
            base.kind, base.data, jnp.zeros((capacity,), jnp.int32)
        )
        self._free = list(range(capacity - 1, -1, -1))  # pop() -> slot 0 first
        self._occupied: set[int] = set()

    # -- slot lifecycle ----------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> int:
        return len(self._occupied)

    def alloc(self) -> int:
        """Claim a free slot and reset its length to 0 (recycled KV
        beyond length 0 is masked, never cleared)."""
        if not self._free:
            raise RuntimeError("KVPool exhausted: no free slots")
        slot = self._free.pop()
        self._occupied.add(slot)
        self.cache = Cache(
            self.cache.kind,
            self.cache.data,
            self.cache.length.at[slot].set(0),
        )
        return slot

    def free(self, slot: int) -> None:
        """Return a slot to the pool (idempotence is a bug: double-free
        raises, catching scheduler accounting errors early)."""
        if slot not in self._occupied:
            raise RuntimeError(f"free() of slot {slot} not in use")
        self._occupied.remove(slot)
        self._free.append(slot)
        if obs.enabled():
            obs.counter("serve.slots_recycled", 1)

    def check_invariants(self) -> None:
        """Pool accounting must always partition the slot set exactly."""
        free, occ = set(self._free), self._occupied
        assert len(free) == len(self._free), "free list has duplicates"
        assert not (free & occ), f"slots both free and occupied: {free & occ}"
        assert len(free) + len(occ) == self.capacity, (
            f"slot leak: {len(free)} free + {len(occ)} active "
            f"!= capacity {self.capacity}"
        )

    # -- device state ------------------------------------------------------

    def lengths(self) -> jnp.ndarray:
        """Per-slot cache lengths, ``(capacity,)`` int32."""
        return self.cache.length

    def set_cache(self, data, lengths) -> None:
        """Install the post-step cache tensors + per-slot lengths."""
        self.cache = Cache(self.cache.kind, data, lengths)
