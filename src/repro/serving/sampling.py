"""Sampling on merge-sorted logits — the serving-side use of the paper.

top-k uses the merge-based tournament top-k; top-p (nucleus) keeps the
merge-sorted prefix whose boundary is found with the engine's
value-keyed cut, so equal logits resolve toward the lower token id —
deterministic tie-breaking across compilations, which lexicographic
float sorts do not guarantee.

Two call shapes:

* the per-request references (:func:`sample_topk` / :func:`sample_topp`)
  vmap a single-row tournament per request — the semantics oracle;
* the batched serving forms (:func:`sample_topk_batched` /
  :func:`sample_topp_batched`) push the whole decode batch through
  ``merge_topk_batch``: every active request's per-block candidate runs
  are concatenated into one ``(b * r, k)`` run matrix and cut with **one
  ``merge_kway_ranked`` call per tournament round** — the round count
  depends only on the vocab/fanout geometry, never on the batch size,
  which is where the sub-linear decode-step scaling in
  ``BENCH_serve.json`` comes from.  Per-request results are bit-identical
  to the references (asserted in ``tests/test_serving.py`` on
  duplicate-heavy, ±inf and dtype-max logits), so the serving engine can
  use either interchangeably.

The top-p nucleus boundary is the degenerate Lemma-1 search of
``repro.core.engine.value_cut_counts`` — the cumulative-probability run
is sorted, so the cut at boundary value ``p`` is one ``searchsorted``
per request, the same machinery the dropless-MoE segment cuts use.

``fanout`` (candidate lists merged per tournament round) threads down
from ``ModelConfig.fanout`` so serving sweeps can tune the fan-out>2
path end-to-end; 0 picks the library default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import engine
from repro.core.topk import (
    candidate_blocks,
    merge_topk,
    merge_topk_batch,
    tournament_rounds,
)

__all__ = [
    "sample_greedy",
    "sample_topk",
    "sample_topp",
    "sample_topk_batched",
    "sample_topp_batched",
    "batched_topk",
]


# ---------------------------------------------------------------------------
# per-request references (the semantics oracle)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k", "fanout"))
def sample_topk(key, logits, k: int = 50, temperature: float = 1.0,
                fanout: int = 0):
    """logits: (b, vocab) -> token ids (b,) sampled from the top-k set."""

    def one(key_i, row):
        vals, idx = merge_topk(row, k, fanout=fanout)
        probs = jax.nn.softmax(vals.astype(jnp.float32) / temperature)
        choice = jax.random.categorical(key_i, jnp.log(probs + 1e-20))
        return idx[choice]

    keys = jax.random.split(key, logits.shape[0])
    return jax.vmap(one)(keys, logits)


@functools.partial(jax.jit, static_argnames=("k", "fanout"))
def sample_topp(key, logits, p: float = 0.9, k: int = 256,
                temperature: float = 1.0, fanout: int = 0):
    """Nucleus sampling over merge-sorted top-k candidates."""

    def one(key_i, row):
        # descending, stable
        vals, idx = merge_topk(row, k, fanout=fanout)
        probs = jax.nn.softmax(vals.astype(jnp.float32) / temperature)
        cum = jnp.cumsum(probs)
        keep = cum - probs < p  # first token always kept
        probs = jnp.where(keep, probs, 0.0)
        choice = jax.random.categorical(key_i, jnp.log(probs + 1e-20))
        return idx[choice]

    keys = jax.random.split(key, logits.shape[0])
    return jax.vmap(one)(keys, logits)


@jax.jit
def sample_greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# batched serving forms: one merge cut per round for the whole batch
# ---------------------------------------------------------------------------


def _record_topk_metrics(b: int, n: int, k: int, fanout: int) -> None:
    """Static tournament geometry -> the ``serve.topk_*`` evidence: the
    number of merge cuts a step costs (batch-size independent) and the
    candidate count entering the final cut."""
    if not obs.enabled():
        return
    _, nb = candidate_blocks(n, k)
    rounds = tournament_rounds(nb, fanout)
    final_runs = rounds[-1] if rounds else 1
    obs.gauge("serve.topk_merge_rounds", len(rounds),
              batch=b, blocks=nb, fanout=fanout or 0)
    obs.counter("serve.topk_candidates", b * final_runs * k,
                batch=b, k=k)


@functools.partial(jax.jit, static_argnames=("k", "fanout"))
def batched_topk(logits, k: int = 50, fanout: int = 0):
    """Row-wise ``(values, indices)`` top-k of a ``(b, vocab)`` batch via
    one ``merge_kway_ranked`` cut per tournament round (see module
    docstring).  Bit-identical per row to ``merge_topk(logits[i], k)``.
    """
    b, n = logits.shape
    _record_topk_metrics(b, n, k, fanout)
    return merge_topk_batch(logits, k, fanout=fanout)


@functools.partial(jax.jit, static_argnames=("k", "fanout"))
def sample_topk_batched(keys, logits, k: int = 50,
                        temperature: float = 1.0, fanout: int = 0):
    """Batched top-k sampling with explicit per-request keys.

    ``keys``: (b,) PRNG keys, one per request — the serving engine
    derives them from (request id, token index) so a request's stream
    never depends on which slot or step it lands in.  Token draws are
    bit-identical to ``sample_topk``'s per-request path given the same
    per-row key.
    """
    vals, idx = batched_topk(logits, k, fanout=fanout)
    probs = jax.nn.softmax(vals.astype(jnp.float32) / temperature, axis=-1)
    choice = jax.vmap(
        lambda kk, pp: jax.random.categorical(kk, jnp.log(pp + 1e-20))
    )(keys, probs)
    return jnp.take_along_axis(idx, choice[:, None], axis=1)[:, 0]


@functools.partial(jax.jit, static_argnames=("k", "fanout"))
def sample_topp_batched(keys, logits, p: float = 0.9, k: int = 256,
                        temperature: float = 1.0, fanout: int = 0):
    """Batched nucleus sampling; the nucleus boundary per request is the
    engine's value-keyed cut into the sorted cumulative-probability run
    (``value_cut_counts`` — one ``searchsorted`` per request, exactly the
    MoE segment-cut machinery), equivalent to the reference's
    ``cum - probs < p`` prefix because that run is nondecreasing.
    """
    vals, idx = batched_topk(logits, k, fanout=fanout)
    probs = jax.nn.softmax(vals.astype(jnp.float32) / temperature, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    n_keep = jax.vmap(
        lambda row: engine.value_cut_counts(row, jnp.float32(p))
    )(cum - probs)
    keep = jnp.arange(k, dtype=jnp.int32)[None, :] < n_keep[:, None]
    probs = jnp.where(keep, probs, 0.0)
    choice = jax.vmap(
        lambda kk, pp: jax.random.categorical(kk, jnp.log(pp + 1e-20))
    )(keys, probs)
    return jnp.take_along_axis(idx, choice[:, None], axis=1)[:, 0]
