"""Sampling on merge-sorted logits — the serving-side use of the paper.

top-k uses the merge-based tournament top-k; top-p (nucleus) sorts the
kept logits with the stable merge sort, so equal logits resolve toward the
lower token id — deterministic tie-breaking across compilations, which
lexicographic float sorts do not guarantee.

``fanout`` (candidate lists merged per tournament round) threads down
from ``ModelConfig.fanout`` so serving sweeps can tune the fan-out>2
path end-to-end; 0 picks the library default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.topk import merge_topk


@functools.partial(jax.jit, static_argnames=("k", "fanout"))
def sample_topk(key, logits, k: int = 50, temperature: float = 1.0,
                fanout: int = 0):
    """logits: (b, vocab) -> token ids (b,) sampled from the top-k set."""

    def one(key_i, row):
        vals, idx = merge_topk(row, k, fanout=fanout)
        probs = jax.nn.softmax(vals.astype(jnp.float32) / temperature)
        choice = jax.random.categorical(key_i, jnp.log(probs + 1e-20))
        return idx[choice]

    keys = jax.random.split(key, logits.shape[0])
    return jax.vmap(one)(keys, logits)


@functools.partial(jax.jit, static_argnames=("k", "fanout"))
def sample_topp(key, logits, p: float = 0.9, k: int = 256,
                temperature: float = 1.0, fanout: int = 0):
    """Nucleus sampling over merge-sorted top-k candidates."""

    def one(key_i, row):
        # descending, stable
        vals, idx = merge_topk(row, k, fanout=fanout)
        probs = jax.nn.softmax(vals.astype(jnp.float32) / temperature)
        cum = jnp.cumsum(probs)
        keep = cum - probs < p  # first token always kept
        probs = jnp.where(keep, probs, 0.0)
        choice = jax.random.categorical(key_i, jnp.log(probs + 1e-20))
        return idx[choice]

    keys = jax.random.split(key, logits.shape[0])
    return jax.vmap(one)(keys, logits)


@jax.jit
def sample_greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
