"""Continuous-batching decode service built on the merge engine.

Layout:

* :mod:`repro.serving.scheduler` — FIFO queue + per-slot request
  progress (pure host bookkeeping, property-tested);
* :mod:`repro.serving.kv_pool` — fixed-capacity recyclable KV slots over
  one shared :class:`~repro.models.transformer.Cache` with per-slot
  lengths (stale KV is masked, never zeroed);
* :mod:`repro.serving.sampling` — per-request reference samplers and the
  batched serving forms whose top-k cuts the whole batch's candidate
  runs with one ``merge_kway_ranked`` call per tournament round;
* :mod:`repro.serving.engine` — :class:`DecodeEngine`, the per-step
  admit → ragged decode → batched sample → retire loop.

Entry point: ``launch/serve.py`` (``python -m repro.launch.serve``).
"""

from repro.serving.engine import DecodeEngine
from repro.serving.kv_pool import KVPool
from repro.serving.sampling import (
    batched_topk,
    sample_greedy,
    sample_topk,
    sample_topk_batched,
    sample_topp,
    sample_topp_batched,
)
from repro.serving.scheduler import Request, Scheduler, SlotState

__all__ = [
    "DecodeEngine",
    "KVPool",
    "Request",
    "Scheduler",
    "SlotState",
    "batched_topk",
    "sample_greedy",
    "sample_topk",
    "sample_topk_batched",
    "sample_topp",
    "sample_topp_batched",
]
