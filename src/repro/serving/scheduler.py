"""FIFO request scheduler for per-step (iteration-level) admission.

Continuous batching admits work between *decode steps*, not between
requests: every step, queued requests move into free pool slots, every
active slot advances one token (prompt tokens are fed through the same
ragged decode path as generated ones), and finished slots are recycled
before the next step's admission.  The scheduler is pure host-side
bookkeeping — deterministic, device-free, and property-tested in
isolation (``tests/test_serving.py``: no slot leak under random
admit/complete traces, FIFO admission fairness).

Invariants it maintains (checked by :meth:`Scheduler.check_invariants`):

* every submitted request is in exactly one of: queue, a slot, done;
* admission order == submission order (FIFO — no request overtakes
  another into a slot);
* at most ``queue_depth`` requests wait; ``submit`` refuses beyond that
  (back-pressure is the caller's problem, by design).
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro import obs

__all__ = ["Request", "SlotState", "Scheduler"]


@dataclasses.dataclass
class Request:
    """One decode request: prompt in, ``max_new_tokens`` out."""

    rid: int
    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclasses.dataclass
class SlotState:
    """Per-slot progress of the resident request.

    ``fed`` counts prompt tokens already pushed through the decode path;
    the slot starts sampling on the step that feeds its last prompt
    token (that step's logits are the first next-token distribution).
    """

    request: Request
    fed: int = 0  # prompt tokens consumed
    generated: int = 0
    tokens: list = dataclasses.field(default_factory=list)

    @property
    def next_feed(self) -> int:
        """Token to feed this step: prompt while prefilling, else the
        previously sampled token."""
        if self.fed < self.request.prompt.size:
            return int(self.request.prompt[self.fed])
        return self.tokens[-1]

    @property
    def samples_this_step(self) -> bool:
        """Will this step's logits be sampled for this slot?  True once
        the token fed this step is the prompt's last (or any generated
        one) and the request still wants tokens."""
        return (
            self.fed >= self.request.prompt.size - 1
            and self.generated < self.request.max_new_tokens
        )

    @property
    def done(self) -> bool:
        return self.generated >= self.request.max_new_tokens


class Scheduler:
    """FIFO admission into a fixed set of decode slots."""

    def __init__(self, max_batch: int, queue_depth: int):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.max_batch = max_batch
        self.queue_depth = queue_depth
        self.queue: deque[Request] = deque()
        self.slots: list[SlotState | None] = [None] * max_batch
        self.done: list[Request] = []
        self._submitted = 0
        self._admitted_rids: list[int] = []
        self._submitted_rids: list[int] = []

    # -- intake ------------------------------------------------------------

    def submit(self, request: Request) -> bool:
        """Queue a request; ``False`` when the queue is at depth (the
        caller sheds load or retries — nothing is silently dropped)."""
        if len(self.queue) >= self.queue_depth:
            return False
        self.queue.append(request)
        self._submitted += 1
        self._submitted_rids.append(request.rid)
        if obs.enabled():
            obs.gauge("serve.queue_depth", len(self.queue))
        return True

    # -- per-step transitions ---------------------------------------------

    def admit(self, free_slots: list[int]) -> list[tuple[int, Request]]:
        """Move queued requests into ``free_slots`` (FIFO), returning the
        ``(slot, request)`` placements made this step."""
        placed = []
        for slot in free_slots:
            if not self.queue:
                break
            req = self.queue.popleft()
            self.slots[slot] = SlotState(req)
            self._admitted_rids.append(req.rid)
            placed.append((slot, req))
        if placed and obs.enabled():
            obs.counter("serve.admitted", len(placed))
            obs.gauge("serve.queue_depth", len(self.queue))
        return placed

    def complete(self, slot: int) -> Request:
        """Retire the request in ``slot`` (the pool recycles the slot)."""
        state = self.slots[slot]
        if state is None:
            raise RuntimeError(f"complete() of empty slot {slot}")
        self.slots[slot] = None
        self.done.append(state.request)
        if obs.enabled():
            obs.counter("serve.completed", 1)
        return state.request

    # -- views -------------------------------------------------------------

    @property
    def active_slots(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def queued(self) -> int:
        return len(self.queue)

    @property
    def pending(self) -> int:
        """Requests not yet retired (queued + resident)."""
        return len(self.queue) + self.active_slots

    def occupied(self) -> list[tuple[int, SlotState]]:
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def check_invariants(self) -> None:
        """Conservation + FIFO: every request is in exactly one place,
        and slot admission never reordered the submit sequence."""
        queued = [r.rid for r in self.queue]
        resident = [s.request.rid for s in self.slots if s is not None]
        retired = [r.rid for r in self.done]
        seen = queued + resident + retired
        assert len(seen) == len(set(seen)), f"request duplicated: {seen}"
        assert len(seen) == self._submitted, (
            f"request leak: {len(seen)} tracked != {self._submitted} submitted"
        )
        assert self.active_slots <= self.max_batch
        assert len(self.queue) <= self.queue_depth
        # FIFO: admitted order is a prefix-order-preserving subsequence of
        # submit order — equal as sequences since nothing else admits.
        expect = [r for r in self._submitted_rids
                  if r in set(self._admitted_rids)]
        assert self._admitted_rids == expect, (
            f"admission reordered: {self._admitted_rids} vs {expect}"
        )
