"""Continuous-batching decode engine: admission -> ragged step -> sample.

One :class:`DecodeEngine` owns a :class:`~repro.serving.scheduler.Scheduler`
(FIFO queue + per-slot progress), a :class:`~repro.serving.kv_pool.KVPool`
(fixed-capacity recyclable cache slots) and two jitted device functions
that are compiled **once** for the pool shape, no matter how occupancy
churns:

* the ragged decode step (``decode_step_ragged``): every slot advances
  one token at its own position; inactive slots ride along masked (their
  lengths are held back, so their writes are never readable history);
* the batched sampler: the whole batch's candidate runs cut by one
  ``merge_kway_ranked`` call per tournament round
  (``repro.serving.sampling``).

Prompt tokens are fed through the same decode path as generated ones
(iteration-level scheduling), so a request admitted at step ``t`` starts
contributing to the batch immediately — no separate prefill entrypoint,
no recompilation, no barrier on the other slots.

Determinism contract: a request's token stream is a pure function of
``(engine seed, request id, prompt, sampler settings)`` — sampling keys
are derived by folding ``(rid, token index)`` into the seed, never the
slot or step index — so streams are byte-identical across runs,
compilations, and any admission interleaving.  ``tests/test_serving.py``
pins this.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.models.transformer import Cache, decode_step_ragged
from repro.serving.kv_pool import KVPool
from repro.serving.sampling import (
    sample_greedy,
    sample_topk_batched,
    sample_topp_batched,
)
from repro.serving.scheduler import Request, Scheduler

__all__ = ["DecodeEngine"]


class DecodeEngine:
    """Serve decode requests with per-step admission over a slot pool."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int,
                 max_batch: int = 0, queue_depth: int = 0,
                 sampler: str = "topk", top_k: int = 50, top_p: float = 0.9,
                 temperature: float = 1.0, seed: int = 42,
                 cache_dtype=jnp.bfloat16):
        if sampler not in ("greedy", "topk", "topp"):
            raise ValueError(f"unknown sampler {sampler!r}")
        max_batch = max_batch or cfg.max_batch
        queue_depth = queue_depth or cfg.queue_depth
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.sampler = sampler
        self.top_k = min(top_k, cfg.vocab)
        self.top_p = top_p
        self.temperature = temperature
        self.pool = KVPool(cfg, max_batch, max_len, cache_dtype)
        self.scheduler = Scheduler(max_batch, queue_depth)
        self.results: dict[int, list[int]] = {}
        self.steps = 0
        self._base_key = jax.random.key(seed)

        def ragged_step(params, cache, tokens, active):
            logits, new_cache = decode_step_ragged(
                cfg, params, cache, tokens, cache.length
            )
            # only active slots bank their position; inactive ones
            # re-write the same masked cell next step
            lengths = jnp.where(active, cache.length + 1, cache.length)
            return logits, Cache(new_cache.kind, new_cache.data, lengths)

        self._step_fn = jax.jit(ragged_step)
        self._keys_fn = jax.jit(
            lambda rids, gens: jax.vmap(
                lambda r, g: jax.random.fold_in(
                    jax.random.fold_in(self._base_key, r), g
                )
            )(rids, gens)
        )

    # -- intake ------------------------------------------------------------

    def submit(self, request: Request) -> bool:
        """Queue a request; rejects (False) on a full queue or a request
        that cannot fit the pool's per-slot sequence capacity."""
        need = request.prompt.size + request.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"request {request.rid}: prompt + max_new_tokens = {need} "
                f"exceeds pool max_len {self.max_len}"
            )
        return self.scheduler.submit(request)

    # -- one engine step ---------------------------------------------------

    def _sample(self, keys, logits):
        if self.sampler == "greedy":
            return sample_greedy(logits)
        if self.sampler == "topk":
            return sample_topk_batched(
                keys, logits, k=self.top_k, temperature=self.temperature,
                fanout=self.cfg.fanout,
            )
        return sample_topp_batched(
            keys, logits, p=self.top_p, k=min(self.top_k, self.cfg.vocab),
            temperature=self.temperature, fanout=self.cfg.fanout,
        )

    def step(self) -> dict:
        """Admit, advance every active slot one token, sample, retire.

        Returns ``{"admitted": [rids], "sampled": {rid: token},
        "completed": [rids], "active": int}`` for the caller's loop.
        """
        sched, pool = self.scheduler, self.pool
        t0 = time.perf_counter()

        n_free = min(pool.free_slots, sched.queued)
        placed = sched.admit([pool.alloc() for _ in range(n_free)])
        if obs.enabled():
            obs.gauge("serve.active_slots", sched.active_slots,
                      capacity=pool.capacity)
        occupied = sched.occupied()
        if not occupied:
            return {"admitted": [], "sampled": {}, "completed": [],
                    "active": 0}

        b = pool.capacity
        tokens = np.zeros((b, 1), np.int32)
        active = np.zeros((b,), bool)
        due = np.zeros((b,), bool)
        rids = np.zeros((b,), np.uint32)
        gens = np.zeros((b,), np.uint32)
        for slot, st in occupied:
            tokens[slot, 0] = st.next_feed
            active[slot] = True
            due[slot] = st.samples_this_step
            rids[slot] = st.request.rid
            gens[slot] = st.generated

        logits, cache = self._step_fn(
            self.params, pool.cache, jnp.asarray(tokens), jnp.asarray(active)
        )
        pool.set_cache(cache.data, cache.length)
        keys = self._keys_fn(jnp.asarray(rids), jnp.asarray(gens))
        nxt = np.asarray(self._sample(keys, logits))  # blocks: step done

        sampled: dict[int, int] = {}
        completed: list[int] = []
        for slot, st in occupied:
            if st.fed < st.request.prompt.size:
                st.fed += 1
            if due[slot]:
                tok = int(nxt[slot])
                st.tokens.append(tok)
                st.generated += 1
                sampled[st.request.rid] = tok
            if st.done:
                req = sched.complete(slot)
                pool.free(slot)
                self.results[req.rid] = list(st.tokens)
                completed.append(req.rid)

        self.steps += 1
        if obs.enabled():
            obs.gauge("serve.step_latency",
                      (time.perf_counter() - t0) * 1e6,
                      batch=len(occupied), unit="us")
        return {"admitted": [r.rid for _, r in placed], "sampled": sampled,
                "completed": completed, "active": len(occupied)}

    # -- drive -------------------------------------------------------------

    @property
    def pending(self) -> int:
        return self.scheduler.pending

    def run(self, max_steps: int = 100_000,
            arrivals=None) -> dict[int, list[int]]:
        """Step until every submitted request retires.

        ``arrivals``: optional iterable of ``(step, Request)`` injected
        when the engine reaches that step — the staggered-arrival test
        harness.  Returns ``{rid: generated tokens}``.
        """
        schedule = sorted(arrivals or [], key=lambda a: a[0])
        i = 0
        while True:
            while i < len(schedule) and schedule[i][0] <= self.steps:
                if not self.submit(schedule[i][1]):
                    break  # queue full: retry next step
                i += 1
            if self.pending == 0 and i == len(schedule):
                return dict(self.results)
            if self.steps >= max_steps:
                raise RuntimeError(
                    f"engine did not drain in {max_steps} steps "
                    f"({self.pending} pending)"
                )
            self.step()
