"""Deterministic, resumable data pipeline with merge-sort length bucketing.

Production constraints this implements:

* **Determinism / resumability**: sample identity is a pure function of
  (seed, epoch, index) — a restarted job regenerates the exact stream with
  no state files (fault tolerance: DESIGN.md §8).
* **Sharding**: each data-parallel rank reads a disjoint strided slice.
* **Length bucketing via the paper's sort**: documents are stably
  merge-sorted by length before packing, so each global batch packs
  near-equal token counts; stability keeps document order deterministic
  within a length class (important for reproducible curriculum).
* **Packing**: greedy fill of (seq_len)-token rows from the sorted stream
  with EOS separators and loss-mask for padding.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Iterator

import numpy as np

from repro.core.mergesort import sort_key_val
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch: int  # per-host batch
    seed: int = 0
    mean_doc_len: int = 512
    eos: int = 0
    fanout: int = 0  # length-bucketing merge-sort fan-out; 0 = default
    # Out-of-core tier (repro.external): windows of >= external_threshold
    # documents bucket through the spill-to-host external sort instead of
    # the on-device sort; 0 = always in-memory.  external_workdir holds
    # the spill files ('' = a per-process temp directory).
    external_threshold: int = 0
    external_workdir: str = ""


def synthetic_doc(dc: DataConfig, epoch: int, idx: int) -> np.ndarray:
    """A deterministic 'document' with *learnable* structure: an affine
    successor chain ``t_{n+1} = (a * t_n + c) mod V`` with occasional random
    restarts — a model that learns the per-(a, c) transition drives loss
    well below log V, so end-to-end training descends measurably."""
    rng = np.random.default_rng(
        np.uint64(dc.seed) * np.uint64(1_000_003)
        + np.uint64(epoch) * np.uint64(10_007)
        + np.uint64(idx)
    )
    ln = int(rng.integers(dc.mean_doc_len // 4, dc.mean_doc_len * 2))
    stride = int(rng.integers(1, 4))  # per-doc stride, inferable from context
    alphabet = min(dc.vocab - 1, 1024)
    out = np.empty(ln, np.int64)
    t = int(rng.integers(0, alphabet))
    for i in range(ln):
        out[i] = 1 + t
        if rng.random() < 0.02:  # restart: irreducible entropy floor
            t = int(rng.integers(0, alphabet))
        else:
            t = (t + stride) % alphabet
    return out.astype(np.int32)


def bucket_by_length(
    lengths: np.ndarray,
    fanout: int = 0,
    *,
    external_threshold: int = 0,
    external_workdir: str = "",
) -> np.ndarray:
    """Stable merge-argsort of document lengths (the paper's sort).

    Past ``external_threshold`` documents the permutation is computed by
    the out-of-core tier (``repro.external``): device-sized chunks are
    sorted and spilled, then co-rank-stream-merged — same stable order,
    bounded device residency.  Below it (or at 0) the in-memory k-way
    merge sort runs as before.
    """
    n = len(lengths)
    if external_threshold and n >= external_threshold:
        from repro.external.api import external_argsort

        workdir = external_workdir or os.path.join(
            tempfile.gettempdir(), f"repro-external-{os.getpid()}"
        )
        # Chunk at half the threshold so crossing it genuinely exercises
        # the spill+merge path (>= 2 runs) rather than a 1-run no-op.
        chunk = max(1, external_threshold // 2)
        order = external_argsort(
            np.asarray(lengths, np.int32),
            chunk=chunk,
            workdir=os.path.join(workdir, "bucket"),
            resume=False,
        )
        return np.asarray(order)
    keys = jnp.asarray(lengths, jnp.int32)
    _, order = sort_key_val(
        keys, jnp.arange(n, dtype=jnp.int32), fanout=fanout
    )
    return np.asarray(order)


def pack_documents(docs, dc: DataConfig):
    """Pack docs into (batch, seq_len) rows with EOS separators.

    Returns tokens, labels (shift-by-one), mask (0 on pad)."""
    rows = np.full((dc.batch, dc.seq_len + 1), dc.eos, np.int32)
    mask = np.zeros((dc.batch, dc.seq_len + 1), np.float32)
    r, col = 0, 0
    for doc in docs:
        take = doc[: dc.seq_len]  # clamp overlong docs
        while len(take) and r < dc.batch:
            space = dc.seq_len + 1 - col
            n = min(space, len(take) + 1)  # +1 for EOS
            rows[r, col : col + n - 1] = take[: n - 1]
            mask[r, col : col + n - 1] = 1.0
            col += n
            take = take[n - 1 :]
            if col >= dc.seq_len + 1:
                r, col = r + 1, 0
        if r >= dc.batch:
            break
    tokens = rows[:, :-1]
    labels = rows[:, 1:]
    return tokens, labels.astype(np.int32), mask[:, 1:]


def batches(dc: DataConfig, *, rank: int = 0, world: int = 1,
            start_step: int = 0) -> Iterator[dict]:
    """Infinite deterministic batch stream for one data-parallel rank.

    ``start_step`` resumes mid-epoch after a restart (pure recomputation).
    Each step consumes a window of documents, buckets them by length with
    the stable merge sort, and packs.
    """
    docs_per_step = dc.batch * max(dc.seq_len // dc.mean_doc_len, 1) * 2
    step = start_step
    while True:
        epoch = step >> 20
        base = (step % (1 << 20)) * docs_per_step * world
        idxs = [base + rank + world * i for i in range(docs_per_step)]
        docs = [synthetic_doc(dc, epoch, i) for i in idxs]
        workdir = dc.external_workdir and os.path.join(
            dc.external_workdir, f"rank{rank}"
        )
        order = bucket_by_length(
            np.asarray([len(d) for d in docs]),
            fanout=dc.fanout,
            external_threshold=dc.external_threshold,
            external_workdir=workdir,
        )
        docs = [docs[i] for i in order]
        tokens, labels, mask = pack_documents(docs, dc)
        yield {
            "tokens": jnp.asarray(tokens),
            "labels": jnp.asarray(labels),
            "mask": jnp.asarray(mask),
            "step": step,
        }
        step += 1
