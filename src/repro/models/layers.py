"""Shared model building blocks (pure-JAX, functional, dict-of-arrays params).

Every ``init_*`` returns ``(params, specs)`` — a pytree of arrays and a
matching pytree of logical ``PartitionSpec``s (DESIGN.md §5): TP shards the
"wide" axis on ``model``, FSDP shards the d_model axis on ``data``; the
``pod`` axis is pure data parallelism.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any  # nested dict of jnp arrays
Specs = Any  # matching nested dict of PartitionSpec

# Activation batch axes, set by the launcher/dry-run before tracing
# (("pod","data"), ("data",), or () for batch-1 decode).  None disables
# activation constraints (single-device tests).  XLA's sharding propagation
# loses the batch sharding through the embedding gather, so the residual
# stream is re-constrained at every layer boundary — without this the scan
# remat carries are stored *replicated* (~100 GiB/device at train_4k).
_BATCH_AXES: tuple | None = None


def set_batch_axes(ba):
    global _BATCH_AXES
    _BATCH_AXES = ba


def get_batch_axes():
    return _BATCH_AXES


def constrain_batch_leading(x):
    """Shard dim0 over the configured batch axes (residual streams etc.)."""
    if _BATCH_AXES is None:
        return x
    spec = P(_BATCH_AXES, *(None,) * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_spec(x, *entries):
    """Explicit activation constraint (no-op outside a mesh context)."""
    if _BATCH_AXES is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))


def truncated_normal(key, shape, std, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# -- normalisation -----------------------------------------------------------


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": P(None)}


def rmsnorm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


# -- embeddings --------------------------------------------------------------


def init_embedding(key, vocab: int, d: int):
    p = {"table": truncated_normal(key, (vocab, d), 0.02)}
    s = {"table": P("model", "data")}  # vocab TP-sharded, d FSDP-sharded
    return p, s


def embed(params, tokens, dtype=jnp.bfloat16):
    return params["table"].astype(dtype)[tokens]


def unembed(params, x):
    """Project activations to vocab logits (tied or untied table)."""
    return jnp.einsum("bsd,vd->bsv", x, params["table"].astype(x.dtype))


# -- MLP ---------------------------------------------------------------------


def init_mlp(key, d: int, ff: int, kind: str = "swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(ff)
    if kind == "swiglu":
        p = {
            "w_gate": truncated_normal(k1, (d, ff), std_in),
            "w_up": truncated_normal(k2, (d, ff), std_in),
            "w_down": truncated_normal(k3, (ff, d), std_out),
        }
        s = {
            "w_gate": P("data", "model"),
            "w_up": P("data", "model"),
            "w_down": P("model", "data"),
        }
    else:  # gelu
        p = {
            "w_up": truncated_normal(k1, (d, ff), std_in),
            "w_down": truncated_normal(k2, (ff, d), std_out),
        }
        s = {"w_up": P("data", "model"), "w_down": P("model", "data")}
    return p, s


def mlp(params, x, kind: str = "swiglu"):
    dt = x.dtype
    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt))
        h = jax.nn.silu(g) * u
    else:
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt))
        h = jax.nn.gelu(u)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(dt))


# -- rotary embeddings -------------------------------------------------------


def rope_frequencies(head_dim: int, max_pos: int, theta: float = 1e4):
    inv = 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )
    t = jnp.arange(max_pos, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)  # (max_pos, head_dim/2)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x, cos, sin, positions):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    c = cos[positions][..., None, :]  # (..., seq, 1, hd/2)
    s = sin[positions][..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, dtype=jnp.bfloat16):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / (1e4 ** (dim / d))
    pe = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
    return pe.astype(dtype)
