"""Mamba2 (SSD — state-space duality) block, chunked-scan formulation.

Training/prefill uses the block-decomposition from the Mamba2 paper: the
sequence is cut into chunks of length L; within a chunk the SSD dual form
is an (L x L) masked attention-like product, across chunks a ``lax.scan``
carries the (heads, head_dim, d_state) state.  Decode is the O(1) SSM
recurrence on a carried state (no KV cache — this is why the ``long_500k``
cell is *runnable* for SSM/hybrid archs and skipped for full attention).

The merge technique does not apply inside the recurrence (attention-free);
noted in DESIGN.md §6 — the arch still uses it for sampling and data
pipeline, and everything here is shardable on (data: batch, model: heads).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.layers import truncated_normal


def init_mamba2(key, d: int, *, expand: int = 2, headdim: int = 64,
                d_state: int = 128, ngroups: int = 1, d_conv: int = 4):
    d_inner = expand * d
    nheads = d_inner // headdim
    conv_dim = d_inner + 2 * ngroups * d_state
    ks = jax.random.split(key, 6)
    std = 1.0 / math.sqrt(d)
    p = {
        # fused input projection: [x, z, B, C, dt]
        "w_in": truncated_normal(
            ks[0], (d, d_inner * 2 + 2 * ngroups * d_state + nheads), std
        ),
        "conv_w": truncated_normal(ks[1], (d_conv, conv_dim), 0.1),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, nheads, dtype=jnp.float32)
        ),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.expm1(
                jnp.exp(
                    jax.random.uniform(
                        ks[2], (nheads,), jnp.float32,
                        math.log(1e-3), math.log(1e-1),
                    )
                )
            )
        ),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "w_out": truncated_normal(ks[3], (d_inner, d), 1.0 / math.sqrt(d_inner)),
    }
    s = {
        "w_in": P("data", "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "A_log": P("model"),
        "D": P("model"),
        "dt_bias": P("model"),
        "norm_scale": P("model"),
        "w_out": P("model", "data"),
    }
    meta = dict(
        d_inner=d_inner, nheads=nheads, d_state=d_state, ngroups=ngroups,
        d_conv=d_conv, headdim=headdim, conv_dim=conv_dim,
    )
    return p, s, meta


def _split_in(proj, meta):
    d_inner, gs, nheads = (
        meta["d_inner"],
        meta["ngroups"] * meta["d_state"],
        meta["nheads"],
    )
    x = proj[..., :d_inner]
    z = proj[..., d_inner : 2 * d_inner]
    b = proj[..., 2 * d_inner : 2 * d_inner + gs]
    c = proj[..., 2 * d_inner + gs : 2 * d_inner + 2 * gs]
    dt = proj[..., 2 * d_inner + 2 * gs :]
    return x, z, b, c, dt


def _causal_conv(x, w, bias, state=None):
    """Depthwise causal conv along seq.  x: (b, s, ch), w: (k, ch).

    With ``state`` (b, k-1, ch) the conv continues from a decode state;
    returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k)
    )
    y = y + bias.astype(x.dtype)
    new_state = xp[:, -(k - 1) :, :]
    return jax.nn.silu(y), new_state


def _gated_rmsnorm(x, z, scale, eps=1e-6):
    x = x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * scale).astype(x.dtype)


def ssd_chunked(x, dt, b, c, a_log, d_skip, meta, *, chunk: int = 128,
                h0=None):
    """SSD forward.  x: (bt, s, h, p); dt: (bt, s, h); b/c: (bt, s, g, n).

    Returns (y, h_last).  ``h0`` (bt, h, p, n) continues from a state.
    All per-chunk work (the L x L masked-decay product) lives inside the
    chunk scan so live memory is O(L^2) per head, not O(S*L).
    """
    bt, s, h, pdim = x.shape
    g, n = b.shape[2], b.shape[3]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hg = h // g  # heads per B/C group
    a = -jnp.exp(a_log.astype(jnp.float32))  # (h,) negative decay rates
    tri = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]

    # scan-major chunk layout
    xr = x.reshape(bt, nc, chunk, h, pdim).swapaxes(0, 1)
    dtr = dt.reshape(bt, nc, chunk, h).astype(jnp.float32).swapaxes(0, 1)
    br = b.reshape(bt, nc, chunk, g, n).swapaxes(0, 1)
    cr = c.reshape(bt, nc, chunk, g, n).swapaxes(0, 1)

    if h0 is None:
        h0 = jnp.zeros((bt, h, pdim, n), jnp.float32)
    h0g = h0.reshape(bt, g, hg, pdim, n)

    def body(hprev, inp):
        xc, dtc, bc, cc = inp
        xc = xc.astype(jnp.float32)
        bc = bc.astype(jnp.float32)
        cc = cc.astype(jnp.float32)
        l = dtc * a  # (bt, L, h) log decays
        cs = jnp.cumsum(l, axis=1)  # inclusive within-chunk cumulative
        # intra-chunk masked decay: exp(cs[t]-cs[tau]) for t >= tau
        seg = cs[:, :, None, :] - cs[:, None, :, :]  # (bt,L,L,h)
        m = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        mh = m.transpose(0, 3, 1, 2).reshape(bt, g, hg, chunk, chunk)
        scores = jnp.einsum("blgn,bmgn->bglm", cc, bc)
        scores = scores.reshape(bt, g, 1, chunk, chunk)
        dtx = xc * dtc[..., None]  # (bt,L,h,p)
        dtxg = dtx.reshape(bt, chunk, g, hg, pdim)
        y_intra = jnp.einsum("bghlm,bmghp->blghp", scores * mh, dtxg)
        # inter-chunk: contribution of the carried state
        decay_in = jnp.exp(cs).reshape(bt, chunk, g, hg)
        y_inter = jnp.einsum("blgn,bghpn,blgh->blghp", cc, hprev, decay_in)
        # state update
        decay_tail = jnp.exp(cs[:, -1:, :] - cs).reshape(bt, chunk, g, hg)
        hc = jnp.einsum("blgn,blghp,blgh->bghpn", bc, dtxg, decay_tail)
        chunk_decay = jnp.exp(cs[:, -1, :]).reshape(bt, g, hg)
        hnew = hprev * chunk_decay[..., None, None] + hc
        y = (y_intra + y_inter).reshape(bt, chunk, h, pdim)
        return hnew, y.astype(x.dtype)

    h_last, ys = lax.scan(body, h0g, (xr, dtr, br, cr))
    y = ys.swapaxes(0, 1).reshape(bt, s, h, pdim)
    y = y + (
        d_skip.astype(jnp.float32)[None, None, :, None]
        * x.astype(jnp.float32)
    ).astype(x.dtype)
    return y, h_last.reshape(bt, h, pdim, n)


def mamba2_forward(params, meta, x, *, chunk: int = 128, state=None):
    """Full Mamba2 block.  x: (b, s, d).  state = (conv_state, ssm_state)
    for decode continuation (None for training/prefill)."""
    bt, s, d = x.shape
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(x.dtype))
    xs, z, b, c, dt = _split_in(proj, meta)
    conv_in = jnp.concatenate([xs, b, c], axis=-1)
    conv_state = None if state is None else state[0]
    conv_out, new_conv_state = _causal_conv(
        conv_in, params["conv_w"], params["conv_b"], conv_state
    )
    d_inner, gs = meta["d_inner"], meta["ngroups"] * meta["d_state"]
    xs = conv_out[..., :d_inner]
    b = conv_out[..., d_inner : d_inner + gs]
    c = conv_out[..., d_inner + gs :]

    h, pdim = meta["nheads"], meta["headdim"]
    g, n = meta["ngroups"], meta["d_state"]
    xh = xs.reshape(bt, s, h, pdim)
    bg = b.reshape(bt, s, g, n)
    cg = c.reshape(bt, s, g, n)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"]
    )  # (bt, s, h)

    ssm_state = None if state is None else state[1]
    y, h_last = ssd_chunked(
        xh, dt, bg, cg, params["A_log"], params["D"], meta,
        chunk=chunk, h0=ssm_state,
    )
    y = y.reshape(bt, s, d_inner)
    y = _gated_rmsnorm(y, z, params["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    if state is None:
        return out, None
    return out, (new_conv_state, h_last)
