"""Multi-head Latent Attention (DeepSeek-V3).

Train/prefill: decompress the latent ``c_kv`` into per-head K/V and run the
flash attention path (qk dim = nope+rope, v dim = v_head_dim).

Decode: the *absorbed* form — W_uk is folded into the query and W_uv into
the output, so attention runs directly against the compressed cache
``(kv_lora_rank + rope_dim)`` per token.  This is what makes the
``decode_32k`` cell's cache 576 B/token instead of 64 KiB/token and it is
the memory-roofline headline for the deepseek-v3 cells.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import flash_attention
from repro.models.layers import apply_rope, rmsnorm, truncated_normal


def init_mla(key, d, n_heads, *, q_lora_rank, kv_lora_rank,
             qk_nope_head_dim, qk_rope_head_dim, v_head_dim):
    ks = jax.random.split(key, 8)
    qk_head_dim = qk_nope_head_dim + qk_rope_head_dim
    std_d = 1.0 / math.sqrt(d)
    p = {
        "w_dq": truncated_normal(ks[0], (d, q_lora_rank), std_d),
        "q_norm": jnp.ones((q_lora_rank,), jnp.float32),
        "w_uq": truncated_normal(
            ks[1], (q_lora_rank, n_heads, qk_head_dim),
            1.0 / math.sqrt(q_lora_rank),
        ),
        "w_dkv": truncated_normal(ks[2], (d, kv_lora_rank), std_d),
        "kv_norm": jnp.ones((kv_lora_rank,), jnp.float32),
        "w_krope": truncated_normal(ks[3], (d, qk_rope_head_dim), std_d),
        "w_uk": truncated_normal(
            ks[4], (kv_lora_rank, n_heads, qk_nope_head_dim),
            1.0 / math.sqrt(kv_lora_rank),
        ),
        "w_uv": truncated_normal(
            ks[5], (kv_lora_rank, n_heads, v_head_dim),
            1.0 / math.sqrt(kv_lora_rank),
        ),
        "wo": truncated_normal(
            ks[6], (n_heads, v_head_dim, d),
            1.0 / math.sqrt(n_heads * v_head_dim),
        ),
    }
    s = {
        "w_dq": P("data", "model"),
        "q_norm": P(None),
        "w_uq": P(None, "model", None),
        "w_dkv": P("data", None),
        "kv_norm": P(None),
        "w_krope": P("data", None),
        "w_uk": P(None, "model", None),
        "w_uv": P(None, "model", None),
        "wo": P("model", None, "data"),
    }
    return p, s


def mla_latents(params, x, cos, sin, positions, dims):
    """Shared front half: queries + compressed KV latent + rope key.

    Returns q_nope (b,s,h,dn), q_rope (b,s,h,dr), c_kv (b,s,r), k_rope
    (b,s,dr) — ``c_kv``/``k_rope`` are exactly what the decode cache stores.
    """
    dt = x.dtype
    cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(dt))
    cq = rmsnorm({"scale": params["q_norm"]}, cq)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(dt))
    dn, dr = dims["qk_nope_head_dim"], dims["qk_rope_head_dim"]
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, cos, sin, positions)

    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dt))
    c_kv = rmsnorm({"scale": params["kv_norm"]}, c_kv)
    k_rope = jnp.einsum("bsd,dk->bsk", x, params["w_krope"].astype(dt))
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin, positions)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention_train(params, x, cos, sin, positions, dims, *,
                        q_chunk=1024, kv_chunk=1024, causal_skip=False):
    """Prefill/train path: decompress K/V, flash attention, output proj."""
    dt = x.dtype
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = mla_latents(
        params, x, cos, sin, positions, dims
    )
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"].astype(dt))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"].astype(dt))
    h = q_nope.shape[2]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, k_rope.shape[-1]))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    attn = flash_attention(
        q, k, v, causal=True, q_chunk=q_chunk, kv_chunk=kv_chunk,
        causal_skip=causal_skip,
    )
    return jnp.einsum("bshk,hkd->bsd", attn, params["wo"].astype(dt))


def mla_attention_decode(params, x, cos, sin, positions, dims,
                         ckv_cache, krope_cache, cache_len):
    """Absorbed decode: attention against the compressed cache.

    x: (b, 1, d).  ckv_cache: (b, smax, r); krope_cache: (b, smax, dr).
    Returns (out (b,1,d), new_ckv (b,1,r), new_krope (b,1,dr)).
    """
    dt = x.dtype
    q_nope, q_rope, c_kv, k_rope = mla_latents(
        params, x, cos, sin, positions, dims
    )
    # absorb W_uk into the query: (b,1,h,dn) x (r,h,dn) -> (b,1,h,r)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"].astype(dt))
    qk_dim = dims["qk_nope_head_dim"] + dims["qk_rope_head_dim"]
    scale = 1.0 / math.sqrt(qk_dim)
    s_lat = jnp.einsum("bshr,bkr->bshk", q_abs, ckv_cache)
    s_rope = jnp.einsum("bshd,bkd->bshk", q_rope, krope_cache)
    scores = (s_lat + s_rope).astype(jnp.float32) * scale  # (b,1,h,smax)
    smax = ckv_cache.shape[1]
    pos = jnp.arange(smax, dtype=jnp.int32)
    scores = jnp.where(pos[None, None, None, :] < cache_len, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx = jnp.einsum("bshk,bkr->bshr", p, ckv_cache)  # (b,1,h,r)
    # absorb W_uv on the way out: (b,1,h,r) x (r,h,dv) -> (b,1,h,dv)
    out_h = jnp.einsum("bshr,rhk->bshk", ctx, params["w_uv"].astype(dt))
    out = jnp.einsum("bshk,hkd->bsd", out_h, params["wo"].astype(dt))
    return out, c_kv, k_rope
