"""Model assembly for all assigned architecture families.

One functional model: ``init_params`` builds (params, partition-specs),
``train_loss`` / ``prefill`` / ``decode_step`` run it.  Layers are stacked
and driven by ``lax.scan`` (compile time O(1) in depth — required for the
95-layer dry-run cells), with ``jax.checkpoint`` remat on the scan body.

Families:
  dense  — pre-norm GQA transformer (qk-norm / qkv-bias / gelu variants)
  moe    — dense attention + stable-sort-dispatch MoE FFN (+ shared experts,
           optional first-k dense layers, MLA attention for deepseek-v3)
  ssm    — Mamba2 (SSD) stack, attention-free
  hybrid — Mamba2 stack + one *shared* attention block every k layers
  vlm/audio — dense backbone; frontend embeddings are injected over the
           token embeddings for the first ``frontend_tokens`` positions
           (the modality encoder itself is a stub per the assignment).

The vocab dimension is never materialised over the full sequence: the loss
is computed in sequence chunks inside a scan (``_chunked_ce``).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _stack_init(fn, key, n, *args, **kwargs):
    """vmap an init over n layers -> stacked params + specs with leading dim."""
    keys = jax.random.split(key, n)
    sample = fn(keys[0], *args, **kwargs)
    params0, specs = sample[0], sample[1]
    stacked = jax.vmap(lambda k: fn(k, *args, **kwargs)[0])(keys)
    specs = jax.tree.map(
        lambda s: P(*((None,) + tuple(s))), specs,
        is_leaf=lambda s: isinstance(s, P),
    )
    rest = sample[2:] if len(sample) > 2 else ()
    return (stacked, specs, *rest)


def _dense_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    hd = cfg.resolved_head_dim
    if cfg.mla:
        ap, asp = mla_mod.init_mla(
            k1, cfg.d_model, cfg.n_heads,
            q_lora_rank=cfg.q_lora_rank, kv_lora_rank=cfg.kv_lora_rank,
            qk_nope_head_dim=cfg.qk_nope_head_dim,
            qk_rope_head_dim=cfg.qk_rope_head_dim,
            v_head_dim=cfg.v_head_dim,
        )
    else:
        ap, asp = attn_mod.init_gqa(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        )
    mp, msp = L.init_mlp(k2, cfg.d_model, cfg.d_ff, kind=cfg.mlp_kind)
    n1p, n1s = L.init_rmsnorm(cfg.d_model)
    n2p, n2s = L.init_rmsnorm(cfg.d_model)
    p = {"attn": ap, "mlp": mp, "ln1": n1p, "ln2": n2p}
    s = {"attn": asp, "mlp": msp, "ln1": n1s, "ln2": n2s}
    return p, s


def _moe_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p, s = _dense_layer_init(k1, cfg)
    ff = cfg.moe_ff or cfg.d_ff
    mp, msp = moe_mod.init_moe(
        k2, cfg.d_model, ff, cfg.n_experts,
        n_shared=cfg.n_shared_experts, shared_ff=ff * max(cfg.n_shared_experts, 1),
    )
    p["mlp"], s["mlp"] = mp, msp
    return p, s


def _mamba_layer_init(key, cfg: ModelConfig):
    k1, _ = jax.random.split(key)
    mp, msp, meta = ssm_mod.init_mamba2(
        k1, cfg.d_model, expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
        d_state=cfg.ssm_state, ngroups=cfg.ssm_ngroups,
    )
    np_, ns = L.init_rmsnorm(cfg.d_model)
    return {"mamba": mp, "ln": np_}, {"mamba": msp, "ln": ns}, meta


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}

    ep, es = L.init_embedding(ks[0], cfg.vocab, cfg.d_model)
    params["embed"], specs["embed"] = ep, es
    if not cfg.tie_embeddings:
        up, us = L.init_embedding(ks[1], cfg.vocab, cfg.d_model)
        params["unembed"], specs["unembed"] = up, us
    fp, fs = L.init_rmsnorm(cfg.d_model)
    params["final_norm"], specs["final_norm"] = fp, fs

    if cfg.frontend != "none":
        params["frontend_proj"] = L.truncated_normal(
            ks[2], (cfg.d_model, cfg.d_model), 0.02
        )
        specs["frontend_proj"] = P("data", None)

    if cfg.ssm:
        lp, lsp, meta = _stack_init(_mamba_layer_init, ks[3], cfg.n_layers, cfg)
        params["layers"], specs["layers"] = lp, lsp
        if cfg.attn_every:  # hybrid: one shared attention + MLP block
            sp, ss = _dense_layer_init(ks[4], cfg)
            params["shared_attn"], specs["shared_attn"] = sp, ss
        return _cast_params(cfg, params), specs

    if cfg.moe:
        n_moe = cfg.n_layers - cfg.first_k_dense
        if cfg.first_k_dense:
            dp, dsp = _stack_init(
                _dense_layer_init, ks[5], cfg.first_k_dense, cfg
            )
            params["dense_layers"], specs["dense_layers"] = dp, dsp
        lp, lsp = _stack_init(_moe_layer_init, ks[3], n_moe, cfg)
        params["layers"], specs["layers"] = lp, lsp
        return _cast_params(cfg, params), specs

    lp, lsp = _stack_init(_dense_layer_init, ks[3], cfg.n_layers, cfg)
    params["layers"], specs["layers"] = lp, lsp
    return _cast_params(cfg, params), specs


def _cast_params(cfg: ModelConfig, params):
    """Store >=2-D weights in cfg.param_dtype (bf16 for the 671B config);
    norms/biases/scalars stay fp32."""
    dt = jnp.dtype(cfg.param_dtype)
    if dt == jnp.float32:
        return params
    return jax.tree.map(
        lambda p: p.astype(dt) if p.ndim >= 2 and p.dtype == jnp.float32 else p,
        params,
    )


def mamba_meta(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    return dict(
        d_inner=d_inner,
        nheads=d_inner // cfg.ssm_headdim,
        d_state=cfg.ssm_state,
        ngroups=cfg.ssm_ngroups,
        d_conv=4,
        headdim=cfg.ssm_headdim,
        conv_dim=d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state,
    )


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------


def _rope_tables(cfg: ModelConfig, max_pos: int):
    if cfg.pos_emb != "rope":
        return None, None
    hd = (
        cfg.qk_rope_head_dim if cfg.mla else cfg.resolved_head_dim
    )
    return L.rope_frequencies(hd, max_pos, cfg.rope_theta)


def _embed_inputs(cfg, params, tokens, frontend_embeds, dtype):
    x = L.embed(params["embed"], tokens, dtype)
    if cfg.frontend != "none" and frontend_embeds is not None:
        fe = jnp.einsum(
            "bfd,de->bfe", frontend_embeds.astype(dtype),
            params["frontend_proj"].astype(dtype),
        )
        f = fe.shape[1]
        x = jnp.concatenate([fe, x[:, f:]], axis=1)
    if cfg.pos_emb == "sinusoidal":
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model, dtype)
    return x


def _dense_attn_block(cfg, lp, x, cos, sin, positions):
    h = L.rmsnorm(lp["ln1"], x)
    if cfg.mla:
        dims = dict(
            qk_nope_head_dim=cfg.qk_nope_head_dim,
            qk_rope_head_dim=cfg.qk_rope_head_dim,
        )
        a = mla_mod.mla_attention_train(
            lp["attn"], h, cos, sin, positions, dims,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            causal_skip=cfg.causal_skip,
        )
    else:
        q, k, v = attn_mod.qkv_project(
            lp["attn"], h, cos, sin, positions, qk_norm=cfg.qk_norm
        )
        if cfg.flash_vjp:
            fa = attn_mod.make_flash_attention_vjp(
                causal=True,
                q_chunk=min(cfg.q_chunk, q.shape[1]),
                kv_chunk=min(cfg.kv_chunk, k.shape[1]),
            )
            o = fa(q, k, v)
        else:
            o = attn_mod.flash_attention(
                q, k, v, causal=True, q_chunk=cfg.q_chunk,
                kv_chunk=cfg.kv_chunk, causal_skip=cfg.causal_skip,
            )
        a = attn_mod.attention_output(lp["attn"], o, x.dtype)
    return x + a


def _ffn_block(cfg, lp, x, *, moe_layer):
    h = L.rmsnorm(lp["ln2"], x)
    if moe_layer:
        ff = moe_mod.moe_apply(
            lp["mlp"], h, n_experts=cfg.n_experts, top_k=cfg.moe_top_k,
            capacity_factor=cfg.capacity_factor, scoring=cfg.router_scoring,
            use_merge_sort=cfg.use_merge_sort_dispatch,
            dispatch_groups=cfg.moe_dispatch_groups,
            dispatch=cfg.moe_dispatch,
        )
    else:
        ff = L.mlp(lp["mlp"], h, kind=cfg.mlp_kind)
    return x + ff


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def hidden_states(cfg: ModelConfig, params, tokens, frontend_embeds=None):
    """Token/frontend inputs -> final hidden states (b, s, d)."""
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = _embed_inputs(cfg, params, tokens, frontend_embeds, dtype)
    x = L.constrain_batch_leading(x)
    cos, sin = _rope_tables(cfg, s)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    if cfg.ssm:
        meta = mamba_meta(cfg)
        shared = params.get("shared_attn")

        def body(carry, inp):
            xx = L.constrain_batch_leading(carry)
            lp, idx = inp
            h = L.rmsnorm(lp["ln"], xx)
            out, _ = ssm_mod.mamba2_forward(
                lp["mamba"], meta, h, chunk=cfg.ssm_chunk
            )
            xx = xx + out
            if cfg.attn_every:
                def with_attn(y):
                    y = _dense_attn_block(cfg, shared, y, cos, sin, positions)
                    return _ffn_block(cfg, shared, y, moe_layer=False)

                xx = lax.cond(
                    (idx + 1) % cfg.attn_every == 0, with_attn,
                    lambda y: y, xx,
                )
            return xx, None

        x, _ = lax.scan(
            _remat(cfg, body), x,
            (params["layers"], jnp.arange(cfg.n_layers, dtype=jnp.int32)),
        )
        return L.rmsnorm(params["final_norm"], x)

    def dense_body(carry, lp):
        carry = L.constrain_batch_leading(carry)
        xx = _dense_attn_block(cfg, lp, carry, cos, sin, positions)
        xx = _ffn_block(cfg, lp, xx, moe_layer=False)
        return L.constrain_batch_leading(xx), None

    def moe_body(carry, lp):
        carry = L.constrain_batch_leading(carry)
        xx = _dense_attn_block(cfg, lp, carry, cos, sin, positions)
        xx = _ffn_block(cfg, lp, xx, moe_layer=True)
        return L.constrain_batch_leading(xx), None

    if cfg.moe:
        if cfg.first_k_dense:
            x, _ = lax.scan(_remat(cfg, dense_body), x, params["dense_layers"])
        x, _ = lax.scan(_remat(cfg, moe_body), x, params["layers"])
    else:
        x, _ = lax.scan(_remat(cfg, dense_body), x, params["layers"])
    return L.rmsnorm(params["final_norm"], x)


def _unembed_table(cfg, params):
    return params["embed" if cfg.tie_embeddings else "unembed"]["table"]


def _chunked_ce(cfg, params, hidden, labels, mask, chunk: int = 512):
    """Cross-entropy without materialising (b, s, vocab)."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    nc = s // chunk
    table = _unembed_table(cfg, params)
    hr = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
    yr = labels.reshape(b, nc, chunk).swapaxes(0, 1)
    mr = mask.reshape(b, nc, chunk).swapaxes(0, 1)

    def step(carry, inp):
        hc, yc, mc = inp
        logits = jnp.einsum(
            "bcd,vd->bcv", hc, table.astype(hc.dtype)
        ).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        loss = jnp.sum((lse - gold) * mc)
        return (carry[0] + loss, carry[1] + jnp.sum(mc)), None

    (total, count), _ = lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                                 (hr, yr, mr))
    return total / jnp.maximum(count, 1.0)


def train_loss(cfg: ModelConfig, params, batch):
    """batch: {'tokens': (b,s), 'labels': (b,s), 'mask': (b,s),
    optional 'frontend_embeds': (b,f,d)}."""
    hidden = hidden_states(
        cfg, params, batch["tokens"], batch.get("frontend_embeds")
    )
    return _chunked_ce(cfg, params, hidden, batch["labels"], batch["mask"])


def prefill_logits(cfg: ModelConfig, params, tokens, frontend_embeds=None):
    """Inference prefill: full forward, next-token logits for the last
    position only (b, vocab) — the (b, s, vocab) tensor never exists."""
    hidden = hidden_states(cfg, params, tokens, frontend_embeds)
    last = hidden[:, -1, :]
    table = _unembed_table(cfg, params)
    return jnp.einsum("bd,vd->bv", last, table.astype(last.dtype)).astype(
        jnp.float32
    )


# --------------------------------------------------------------------------
# serving: cache init + decode step
# --------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class Cache:
    """Per-family decode cache (stacked over layers).

    ``kind`` is static pytree metadata so Cache flows through jit/pjit;
    ``data``/``length`` are the array children.
    """

    def __init__(self, kind: str, data: Any, length):
        self.kind = kind  # 'gqa' | 'mla' | 'ssm' | 'hybrid'
        self.data = data
        self.length = length

    def tree_flatten(self):
        return (self.data, self.length), self.kind

    @classmethod
    def tree_unflatten(cls, kind, children):
        data, length = children
        return cls(kind, data, length)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Cache:
    ll = cfg.n_layers
    if cfg.ssm:
        meta = mamba_meta(cfg)
        conv = jnp.zeros(
            (ll, batch, meta["d_conv"] - 1, meta["conv_dim"]), dtype
        )
        state = jnp.zeros(
            (ll, batch, meta["nheads"], meta["headdim"], meta["d_state"]),
            jnp.float32,
        )
        if cfg.attn_every:
            napp = cfg.n_layers // cfg.attn_every
            hd = cfg.resolved_head_dim
            k = jnp.zeros((napp, batch, max_len, cfg.n_kv_heads, hd), dtype)
            v = jnp.zeros((napp, batch, max_len, cfg.n_kv_heads, hd), dtype)
            return Cache("hybrid", (conv, state, k, v), jnp.int32(0))
        return Cache("ssm", (conv, state), jnp.int32(0))
    if cfg.mla:
        ckv = jnp.zeros((ll, batch, max_len, cfg.kv_lora_rank), dtype)
        kr = jnp.zeros((ll, batch, max_len, cfg.qk_rope_head_dim), dtype)
        return Cache("mla", (ckv, kr), jnp.int32(0))
    hd = cfg.resolved_head_dim
    k = jnp.zeros((ll, batch, max_len, cfg.n_kv_heads, hd), dtype)
    v = jnp.zeros((ll, batch, max_len, cfg.n_kv_heads, hd), dtype)
    return Cache("gqa", (k, v), jnp.int32(0))


def cache_specs(cfg: ModelConfig, batch_axes) -> Cache:
    """PartitionSpecs matching init_cache's structure.

    KV caches are **sequence-sharded** on the model axis (decode-time
    sequence parallelism): the GQA archs here have n_kv=8 < 16-way TP, so
    head sharding cannot use the mesh, while the 32k/500k sequence always
    divides it.  Softmax over the sharded axis becomes a small all-reduce
    of per-shard (max, sum) — the production ring-attention layout.
    """
    ba = batch_axes
    if cfg.ssm:
        conv = P(None, ba, None, "model")
        state = P(None, ba, "model", None, None)
        if cfg.attn_every:
            kv = P(None, ba, "model", None, None)  # seq-sharded
            return Cache("hybrid", (conv, state, kv, kv), P())
        return Cache("ssm", (conv, state), P())
    if cfg.mla:
        ckv = P(None, ba, "model", None)  # seq-sharded compressed latent
        return Cache("mla", (ckv, ckv), P())
    kv = P(None, ba, "model", None, None)  # seq-sharded
    return Cache("gqa", (kv, kv), P())


def decode_step(cfg: ModelConfig, params, cache: Cache, tokens):
    """One token for every sequence.  tokens: (b, 1) -> logits (b, vocab).

    The scan carries the residual stream and threads per-layer cache slices
    as scan xs/ys, so decode is O(1) HLO in depth as well.
    """
    dtype = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    pos = cache.length
    x = L.embed(params["embed"], tokens, dtype)
    max_len = _cache_max_len(cfg, cache)
    if cfg.pos_emb == "sinusoidal":
        s_table = L.sinusoidal_positions(max_len + 1, cfg.d_model, dtype)
        x = x + s_table[pos][None, None, :]
    cos, sin = _rope_tables(cfg, max_len + 1)
    positions = jnp.full((b, 1), pos, jnp.int32)

    if cfg.ssm:
        x, new_cache = _decode_ssm(cfg, params, cache, x, cos, sin, positions)
    elif cfg.mla:
        x, new_cache = _decode_mla(cfg, params, cache, x, cos, sin, positions)
    else:
        x, new_cache = _decode_gqa(cfg, params, cache, x, cos, sin, positions)

    h = L.rmsnorm(params["final_norm"], x)
    logits = jnp.einsum(
        "bsd,vd->bsv", h, _unembed_table(cfg, params).astype(dtype)
    )
    return logits[:, 0].astype(jnp.float32), new_cache


def decode_step_ragged(cfg: ModelConfig, params, cache: Cache, tokens,
                       lengths):
    """One token for every *slot* at per-slot positions (continuous
    batching).  tokens: (b, 1); lengths: (b,) int32 per-slot cache
    lengths — token ``b`` is written at position ``lengths[b]`` and
    attends over ``lengths[b] + 1`` cache entries.  Returns
    ``(logits (b, vocab), new_cache)`` with ``new_cache.length ==
    lengths + 1`` for every slot; the serving engine holds back the
    lengths of inactive slots itself (they re-write one masked position
    per step, which the per-slot attention mask never reads as history).

    Only the ``gqa`` cache family carries per-slot positions today
    (dense / MoE / VLM / audio archs); MLA and SSM caches raise — the
    serving launcher keeps those archs on the lock-step batch path.
    """
    if cache.kind != "gqa":
        raise NotImplementedError(
            f"continuous-batching decode supports the 'gqa' cache family; "
            f"got {cache.kind!r} (use the lock-step decode_step path)"
        )
    dtype = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    lengths = jnp.asarray(lengths, jnp.int32)
    x = L.embed(params["embed"], tokens, dtype)
    max_len = _cache_max_len(cfg, cache)
    if cfg.pos_emb == "sinusoidal":
        s_table = L.sinusoidal_positions(max_len + 1, cfg.d_model, dtype)
        x = x + s_table[lengths][:, None, :]
    cos, sin = _rope_tables(cfg, max_len + 1)
    positions = lengths[:, None]  # (b, 1) — per-slot rope positions
    x, new_cache = _decode_gqa_ragged(
        cfg, params, cache, x, cos, sin, positions, lengths
    )
    h = L.rmsnorm(params["final_norm"], x)
    logits = jnp.einsum(
        "bsd,vd->bsv", h, _unembed_table(cfg, params).astype(dtype)
    )
    return logits[:, 0].astype(jnp.float32), new_cache


def _decode_gqa_ragged(cfg, params, cache, x, cos, sin, positions, lengths):
    kc, vc = cache.data[0], cache.data[1]
    b = x.shape[0]
    rows = jnp.arange(b, dtype=jnp.int32)

    def make_body(moe_layer):
        def body(xx, inp):
            lp, kl, vl = inp
            h = L.rmsnorm(lp["ln1"], xx)
            q, k, v = attn_mod.qkv_project(
                lp["attn"], h, cos, sin, positions, qk_norm=cfg.qk_norm
            )
            # per-slot scatter: slot b's token lands at its own position
            kl = kl.at[rows, lengths].set(k[:, 0])
            vl = vl.at[rows, lengths].set(v[:, 0])
            o = attn_mod.decode_attention(q, kl, vl, lengths + 1)
            xx = xx + attn_mod.attention_output(lp["attn"], o, xx.dtype)
            xx = _ffn_block(cfg, lp, xx, moe_layer=moe_layer)
            return xx, (kl, vl)

        return body

    layers = params["layers"]
    if cfg.moe and cfg.first_k_dense:
        nd = cfg.first_k_dense
        x, (kd, vd) = lax.scan(
            make_body(False), x, (params["dense_layers"], kc[:nd], vc[:nd])
        )
        x, (km, vm) = lax.scan(make_body(cfg.moe), x, (layers, kc[nd:], vc[nd:]))
        k_new = jnp.concatenate([kd, km], axis=0)
        v_new = jnp.concatenate([vd, vm], axis=0)
    else:
        x, (k_new, v_new) = lax.scan(make_body(cfg.moe), x, (layers, kc, vc))
    return x, Cache("gqa", (k_new, v_new), lengths + 1)


def _cache_max_len(cfg, cache):
    if cache.kind in ("gqa", "hybrid"):
        return cache.data[-1].shape[2]
    if cache.kind == "mla":
        return cache.data[0].shape[2]
    return 1


def _decode_gqa(cfg, params, cache, x, cos, sin, positions):
    kc, vc, pos = cache.data[0], cache.data[1], cache.length

    def body(xx, inp):
        lp, kl, vl = inp
        h = L.rmsnorm(lp["ln1"], xx)
        q, k, v = attn_mod.qkv_project(
            lp["attn"], h, cos, sin, positions, qk_norm=cfg.qk_norm
        )
        kl = lax.dynamic_update_slice(kl, k, (0, pos, 0, 0))
        vl = lax.dynamic_update_slice(vl, v, (0, pos, 0, 0))
        o = attn_mod.decode_attention(q, kl, vl, pos + 1)
        xx = xx + attn_mod.attention_output(lp["attn"], o, xx.dtype)
        xx = _ffn_block(cfg, lp, xx, moe_layer=cfg.moe)
        return xx, (kl, vl)

    layers = params["layers"]
    if cfg.moe and cfg.first_k_dense:
        nd = cfg.first_k_dense

        def dense_body(xx, inp):
            lp, kl, vl = inp
            h = L.rmsnorm(lp["ln1"], xx)
            q, k, v = attn_mod.qkv_project(
                lp["attn"], h, cos, sin, positions, qk_norm=cfg.qk_norm
            )
            kl = lax.dynamic_update_slice(kl, k, (0, pos, 0, 0))
            vl = lax.dynamic_update_slice(vl, v, (0, pos, 0, 0))
            o = attn_mod.decode_attention(q, kl, vl, pos + 1)
            xx = xx + attn_mod.attention_output(lp["attn"], o, xx.dtype)
            xx = _ffn_block(cfg, lp, xx, moe_layer=False)
            return xx, (kl, vl)

        x, (kd, vd) = lax.scan(
            dense_body, x, (params["dense_layers"], kc[:nd], vc[:nd])
        )
        x, (km, vm) = lax.scan(body, x, (layers, kc[nd:], vc[nd:]))
        k_new = jnp.concatenate([kd, km], axis=0)
        v_new = jnp.concatenate([vd, vm], axis=0)
    else:
        x, (k_new, v_new) = lax.scan(body, x, (layers, kc, vc))
    return x, Cache("gqa", (k_new, v_new), cache.length + 1)


def _decode_mla(cfg, params, cache, x, cos, sin, positions):
    ckv_c, kr_c, pos = cache.data[0], cache.data[1], cache.length
    dims = dict(
        qk_nope_head_dim=cfg.qk_nope_head_dim,
        qk_rope_head_dim=cfg.qk_rope_head_dim,
    )

    def body(xx, inp):
        lp, ckv_l, kr_l = inp
        h = L.rmsnorm(lp["ln1"], xx)
        o, new_ckv, new_kr = mla_mod.mla_attention_decode(
            lp["attn"], h, cos, sin, positions, dims, ckv_l, kr_l, pos + 1
        )
        ckv_l = lax.dynamic_update_slice(
            ckv_l, new_ckv.astype(ckv_l.dtype), (0, pos, 0)
        )
        kr_l = lax.dynamic_update_slice(
            kr_l, new_kr.astype(kr_l.dtype), (0, pos, 0)
        )
        xx = xx + o
        xx = _ffn_block(cfg, lp, xx, moe_layer=cfg.moe)
        return xx, (ckv_l, kr_l)

    # NOTE: cache must be updated BEFORE attention sees position `pos`;
    # mla_attention_decode masks with pos+1 but reads the cache arg, so we
    # update first by computing latents inside — handled by updating the
    # cache here prior to the call in a fused pass below.
    def body_fused(xx, inp):
        lp, ckv_l, kr_l = inp
        h = L.rmsnorm(lp["ln1"], xx)
        q_nope, q_rope, c_kv, k_rope = mla_mod.mla_latents(
            lp["attn"], h, cos, sin, positions, dims
        )
        ckv_l = lax.dynamic_update_slice(
            ckv_l, c_kv.astype(ckv_l.dtype), (0, pos, 0)
        )
        kr_l = lax.dynamic_update_slice(
            kr_l, k_rope.astype(kr_l.dtype), (0, pos, 0)
        )
        o, _, _ = mla_mod.mla_attention_decode(
            lp["attn"], h, cos, sin, positions, dims, ckv_l, kr_l, pos + 1
        )
        xx = xx + o
        xx = _ffn_block(cfg, lp, xx, moe_layer=cfg.moe)
        return xx, (ckv_l, kr_l)

    layers = params["layers"]
    if cfg.moe and cfg.first_k_dense:
        nd = cfg.first_k_dense

        def dense_body(xx, inp):
            lp, ckv_l, kr_l = inp
            h = L.rmsnorm(lp["ln1"], xx)
            q_nope, q_rope, c_kv, k_rope = mla_mod.mla_latents(
                lp["attn"], h, cos, sin, positions, dims
            )
            ckv_l = lax.dynamic_update_slice(
                ckv_l, c_kv.astype(ckv_l.dtype), (0, pos, 0)
            )
            kr_l = lax.dynamic_update_slice(
                kr_l, k_rope.astype(kr_l.dtype), (0, pos, 0)
            )
            o, _, _ = mla_mod.mla_attention_decode(
                lp["attn"], h, cos, sin, positions, dims, ckv_l, kr_l,
                pos + 1,
            )
            xx = xx + o
            xx = _ffn_block(cfg, lp, xx, moe_layer=False)
            return xx, (ckv_l, kr_l)

        x, (c_d, r_d) = lax.scan(
            dense_body, x, (params["dense_layers"], ckv_c[:nd], kr_c[:nd])
        )
        x, (c_m, r_m) = lax.scan(body_fused, x, (layers, ckv_c[nd:], kr_c[nd:]))
        ckv_new = jnp.concatenate([c_d, c_m], axis=0)
        kr_new = jnp.concatenate([r_d, r_m], axis=0)
    else:
        x, (ckv_new, kr_new) = lax.scan(body_fused, x, (layers, ckv_c, kr_c))
    return x, Cache("mla", (ckv_new, kr_new), cache.length + 1)


def _decode_ssm(cfg, params, cache, x, cos, sin, positions):
    meta = mamba_meta(cfg)
    pos = cache.length
    if cfg.attn_every:
        conv_c, st_c, kc, vc = cache.data
    else:
        conv_c, st_c = cache.data
        kc = vc = None
    shared = params.get("shared_attn")

    def body(carry, inp):
        xx, kc_, vc_ = carry
        lp, conv_l, st_l, idx = inp
        h = L.rmsnorm(lp["ln"], xx)
        out, (conv_n, st_n) = ssm_mod.mamba2_forward(
            lp["mamba"], meta, h, chunk=1, state=(conv_l, st_l)
        )
        xx = xx + out
        if cfg.attn_every:
            app = idx // cfg.attn_every

            def with_attn(args):
                y, kc2, vc2 = args
                h2 = L.rmsnorm(shared["ln1"], y)
                q, k, v = attn_mod.qkv_project(
                    shared["attn"], h2, cos, sin, positions,
                    qk_norm=cfg.qk_norm,
                )
                ka = lax.dynamic_update_slice(
                    kc2[app], k, (0, pos, 0, 0)
                )
                va = lax.dynamic_update_slice(
                    vc2[app], v, (0, pos, 0, 0)
                )
                o = attn_mod.decode_attention(q, ka, va, pos + 1)
                y = y + attn_mod.attention_output(shared["attn"], o, y.dtype)
                y = _ffn_block(cfg, shared, y, moe_layer=False)
                kc2 = lax.dynamic_update_slice(
                    kc2, ka[None], (app, 0, 0, 0, 0)
                )
                vc2 = lax.dynamic_update_slice(
                    vc2, va[None], (app, 0, 0, 0, 0)
                )
                return y, kc2, vc2

            xx, kc_, vc_ = lax.cond(
                (idx + 1) % cfg.attn_every == 0,
                with_attn,
                lambda a: a,
                (xx, kc_, vc_),
            )
        return (xx, kc_, vc_), (conv_n, st_n)

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    if cfg.attn_every:
        (x, kc, vc), (conv_n, st_n) = lax.scan(
            body, (x, kc, vc), (params["layers"], conv_c, st_c, idxs)
        )
        return x, Cache("hybrid", (conv_n, st_n, kc, vc), cache.length + 1)
    (x, _, _), (conv_n, st_n) = lax.scan(
        body, (x, None, None), (params["layers"], conv_c, st_c, idxs)
    )
    return x, Cache("ssm", (conv_n, st_n), cache.length + 1)
