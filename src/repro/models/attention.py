"""GQA attention: flash-style chunked softmax (pure XLA) + decode path.

``flash_attention`` never materialises the full (S, S) score matrix: an
outer scan over query chunks and an inner scan over KV chunks carry the
online-softmax statistics (running max / normaliser), so per-step live
memory is ``O(q_chunk * kv_chunk)`` — this is what lets the 32k-prefill and
4k-train cells compile within HBM at dry-run time.  ``causal_skip`` prunes
KV chunks strictly above the diagonal (per-q-chunk static upper bound) —
that halving of attention FLOPs is one of the §Perf iterations.

GQA is expressed with a (kv_head, group) einsum layout — KV is never
``repeat``-ed up to n_heads, so decode reads exactly the cache bytes.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.layers import apply_rope, init_rmsnorm, rmsnorm, truncated_normal


def init_gqa(key, d, n_heads, n_kv, head_dim, qkv_bias=False, qk_norm=False):
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p = {
        "wq": truncated_normal(ks[0], (d, n_heads, head_dim), std),
        "wk": truncated_normal(ks[1], (d, n_kv, head_dim), std),
        "wv": truncated_normal(ks[2], (d, n_kv, head_dim), std),
        "wo": truncated_normal(
            ks[3], (n_heads, head_dim, d), 1.0 / math.sqrt(n_heads * head_dim)
        ),
    }
    s = {
        "wq": P("data", "model", None),
        "wk": P("data", "model", None),
        "wv": P("data", "model", None),
        "wo": P("model", None, "data"),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), jnp.float32)
        p["bk"] = jnp.zeros((n_kv, head_dim), jnp.float32)
        p["bv"] = jnp.zeros((n_kv, head_dim), jnp.float32)
        s["bq"] = P("model", None)
        s["bk"] = P("model", None)
        s["bv"] = P("model", None)
    if qk_norm:
        qp, qs = init_rmsnorm(head_dim)
        kp, ksp = init_rmsnorm(head_dim)
        p["q_norm"], p["k_norm"] = qp, kp
        s["q_norm"], s["k_norm"] = qs, ksp
    return p, s


def qkv_project(params, x, cos, sin, positions, qk_norm=False):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if cos is not None:
        q = apply_rope(q, cos, sin, positions)
        k = apply_rope(k, cos, sin, positions)
    return q, k, v


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    causal_skip: bool = False,
):
    """Chunked online-softmax attention (GQA-native).

    q: (b, sq, h, hd); k, v: (b, skv, n_kv, hd).  Returns (b, sq, h, hd).
    ``causal_skip=True`` unrolls the outer q loop in Python and statically
    skips fully-masked KV chunks (the beyond-paper FLOP halving).
    """
    b, sq, h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    hdv = v.shape[3]  # v head dim may differ from qk head dim (MLA)
    g = h // n_kv
    scale = 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, (sq, skv)
    nq = sq // q_chunk
    nkv = skv // kv_chunk

    qr = (q * scale).reshape(b, nq, q_chunk, n_kv, g, hd)
    kr = k.reshape(b, nkv, kv_chunk, n_kv, hd)
    vr = v.reshape(b, nkv, kv_chunk, n_kv, hdv)
    q_pos = jnp.arange(sq, dtype=jnp.int32).reshape(nq, q_chunk)
    kv_pos = jnp.arange(skv, dtype=jnp.int32).reshape(nkv, kv_chunk)

    def kv_step(carry, inputs):
        acc, m, l, qi, qp = carry
        kc, vc, kp = inputs
        # scores: (b, n_kv, g, q_chunk, kv_chunk)
        s = jnp.einsum("bqcgd,bkcd->bcgqk", qi, kc).astype(jnp.float32)
        if causal:
            mask = qp[None, None, None, :, None] >= kp[None, None, None, None, :]
            s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bcgqk,bkcd->bcgqd", p.astype(qi.dtype), vc
        ).astype(jnp.float32)
        return (acc_new, m_new, l_new, qi, qp), None

    def one_q_chunk(qi, qp, kv_hi):
        acc = jnp.zeros((b, n_kv, g, q_chunk, hdv), jnp.float32)
        m = jnp.full((b, n_kv, g, q_chunk), -jnp.inf, jnp.float32)
        l = jnp.zeros((b, n_kv, g, q_chunk), jnp.float32)
        ks_ = kr[:, :kv_hi].swapaxes(0, 1)  # (nkv', b, kc, n_kv, hd)
        vs_ = vr[:, :kv_hi].swapaxes(0, 1)
        ps_ = kv_pos[:kv_hi]
        (acc, m, l, _, _), _ = lax.scan(
            kv_step, (acc, m, l, qi, qp), (ks_, vs_, ps_)
        )
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        # (b, n_kv, g, qc, hd) -> (b, qc, n_kv, g, hd)
        return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(q.dtype)

    if causal_skip and causal and nq > 1:
        outs = []
        for iq in range(nq):
            kv_hi = min(nkv, ((iq + 1) * q_chunk + kv_chunk - 1) // kv_chunk)
            outs.append(one_q_chunk(qr[:, iq], q_pos[iq], kv_hi))
        out = jnp.stack(outs, axis=1)  # (b, nq, qc, n_kv, g, hd)
    else:

        def q_step(_, inputs):
            qi, qp = inputs
            return None, one_q_chunk(qi, qp, nkv)

        _, outs = lax.scan(q_step, None, (qr.swapaxes(0, 1), q_pos))
        out = outs.swapaxes(0, 1)  # (b, nq, qc, n_kv, g, hd)
    return out.reshape(b, sq, h, hdv)


def attention_output(params, attn, x_dtype):
    return jnp.einsum("bshk,hkd->bsd", attn, params["wo"].astype(x_dtype))


# ---------------------------------------------------------------------------
# custom-VJP flash attention: recompute scores in backward (true flash bwd)
# ---------------------------------------------------------------------------
#
# The autodiff backward of the scan-based forward stacks a probability
# matrix per KV chunk as a scan residual — at train_4k that is the single
# largest HBM-traffic line in the dry-run profile.  The flash backward
# stores only (out, m, l) per query and recomputes p chunk-by-chunk.


def _flash_fwd_chunked(q, k, v, causal, q_chunk, kv_chunk):
    """Forward returning (out, m, l); shapes as flash_attention."""
    b, sq, h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    hdv = v.shape[3]
    g = h // n_kv
    scale = 1.0 / math.sqrt(hd)
    nq = sq // q_chunk
    nkv = skv // kv_chunk
    qr = (q * scale).reshape(b, nq, q_chunk, n_kv, g, hd)
    kr = k.reshape(b, nkv, kv_chunk, n_kv, hd)
    vr = v.reshape(b, nkv, kv_chunk, n_kv, hdv)
    q_pos = jnp.arange(sq, dtype=jnp.int32).reshape(nq, q_chunk)
    kv_pos = jnp.arange(skv, dtype=jnp.int32).reshape(nkv, kv_chunk)

    def kv_step(carry, inputs):
        acc, m, l, qi, qp = carry
        kc, vc, kp = inputs
        s = jnp.einsum("bqcgd,bkcd->bcgqk", qi, kc).astype(jnp.float32)
        if causal:
            mask = qp[None, None, None, :, None] >= kp[None, None, None, None, :]
            s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bcgqk,bkcd->bcgqd", p.astype(qi.dtype), vc
        ).astype(jnp.float32)
        return (acc_new, m_new, l_new, qi, qp), None

    def q_step(_, inputs):
        qi, qp = inputs
        acc = jnp.zeros((b, n_kv, g, q_chunk, hdv), jnp.float32)
        m = jnp.full((b, n_kv, g, q_chunk), -jnp.inf, jnp.float32)
        l = jnp.zeros((b, n_kv, g, q_chunk), jnp.float32)
        (acc, m, l, _, _), _ = lax.scan(
            kv_step, (acc, m, l, qi, qp),
            (kr.swapaxes(0, 1), vr.swapaxes(0, 1), kv_pos),
        )
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        return None, (out.astype(q.dtype), m, l)

    _, (outs, ms, ls) = lax.scan(q_step, None, (qr.swapaxes(0, 1), q_pos))
    # outs: (nq, b, c, g, qc, hdv) -> (b, sq, h, hdv)
    out = jnp.transpose(outs, (1, 0, 4, 2, 3, 5)).reshape(b, sq, h, hdv)
    return out, ms, ls  # ms/ls: (nq, b, c, g, qc)


def _flash_bwd_chunked(q, k, v, out, ms, ls, dout, causal, q_chunk, kv_chunk):
    b, sq, h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    hdv = v.shape[3]
    g = h // n_kv
    scale = 1.0 / math.sqrt(hd)
    nq = sq // q_chunk
    nkv = skv // kv_chunk
    qr = q.reshape(b, nq, q_chunk, n_kv, g, hd)
    kr = k.reshape(b, nkv, kv_chunk, n_kv, hd)
    vr = v.reshape(b, nkv, kv_chunk, n_kv, hdv)
    do = dout.reshape(b, nq, q_chunk, n_kv, g, hdv)
    og = out.reshape(b, nq, q_chunk, n_kv, g, hdv)
    q_pos = jnp.arange(sq, dtype=jnp.int32).reshape(nq, q_chunk)
    kv_pos = jnp.arange(skv, dtype=jnp.int32).reshape(nkv, kv_chunk)
    # delta: rowsum(do * out) per query — (nq, b, c, g, qc)
    delta = jnp.einsum("bnqcgd,bnqcgd->nbcgq", do.astype(jnp.float32),
                       og.astype(jnp.float32))

    def q_step(carry, inputs):
        dk_acc, dv_acc = carry
        qi, doi, mi, li, di, qp = inputs
        qs = (qi * scale).astype(q.dtype)

        def kv_step(carry2, inputs2):
            dq_acc, = carry2
            kc, vc, kp, dk_c, dv_c = inputs2
            s = jnp.einsum("bqcgd,bkcd->bcgqk", qs, kc).astype(jnp.float32)
            if causal:
                mask = (
                    qp[None, None, None, :, None]
                    >= kp[None, None, None, None, :]
                )
                s = jnp.where(mask, s, -jnp.inf)
            safe_m = jnp.where(jnp.isfinite(mi), mi, 0.0)
            p = jnp.exp(s - safe_m[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            p = p / jnp.maximum(li, 1e-37)[..., None]  # normalised probs
            pb = p.astype(q.dtype)
            dv_new = dv_c + jnp.einsum(
                "bcgqk,bqcgd->bkcd", pb, doi
            ).astype(jnp.float32)
            dp = jnp.einsum("bqcgd,bkcd->bcgqk", doi, vc).astype(jnp.float32)
            ds = p * (dp - di[..., None])  # (b,c,g,q,k) f32
            dsb = ds.astype(q.dtype)
            dq_new = dq_acc + jnp.einsum(
                "bcgqk,bkcd->bqcgd", dsb, kc
            ).astype(jnp.float32) * scale
            # qs already carries the 1/sqrt(d) factor, so no extra scale
            dk_new = dk_c + jnp.einsum(
                "bcgqk,bqcgd->bkcd", dsb, qs
            ).astype(jnp.float32)
            return (dq_new,), (dk_new, dv_new)

        dq0 = jnp.zeros((b, q_chunk, n_kv, g, hd), jnp.float32)
        (dq_i,), (dk_steps, dv_steps) = lax.scan(
            kv_step, (dq0,),
            (kr.swapaxes(0, 1), vr.swapaxes(0, 1), kv_pos,
             dk_acc.swapaxes(0, 1), dv_acc.swapaxes(0, 1)),
        )
        return (
            dk_steps.swapaxes(0, 1), dv_steps.swapaxes(0, 1)
        ), dq_i.astype(q.dtype)

    dk0 = jnp.zeros((b, nkv, kv_chunk, n_kv, hd), jnp.float32)
    dv0 = jnp.zeros((b, nkv, kv_chunk, n_kv, hdv), jnp.float32)
    (dk, dv), dqs = lax.scan(
        q_step, (dk0, dv0),
        (qr.swapaxes(0, 1), do.swapaxes(0, 1), ms, ls, delta, q_pos),
    )
    dq = dqs.swapaxes(0, 1).reshape(b, sq, h, hd)
    return (
        dq,
        dk.reshape(b, skv, n_kv, hd).astype(k.dtype),
        dv.reshape(b, skv, n_kv, hdv).astype(v.dtype),
    )


def make_flash_attention_vjp(*, causal: bool, q_chunk: int, kv_chunk: int):
    """flash_attention with the flash backward (recompute, no p residuals)."""

    @jax.custom_vjp
    def fa(q, k, v):
        out, _, _ = _flash_fwd_chunked(q, k, v, causal, q_chunk, kv_chunk)
        return out

    def fwd(q, k, v):
        out, ms, ls = _flash_fwd_chunked(q, k, v, causal, q_chunk, kv_chunk)
        return out, (q, k, v, out, ms, ls)

    def bwd(res, dout):
        q, k, v, out, ms, ls = res
        return _flash_bwd_chunked(
            q, k, v, out, ms, ls, dout, causal, q_chunk, kv_chunk
        )

    fa.defvjp(fwd, bwd)
    return fa


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-token decode: q (b, 1, h, hd) vs cache (b, smax, n_kv, hd).

    GQA-native — the cache is read once, never repeated to n_heads.
    ``cache_len`` is the number of valid cache positions: a scalar
    (lock-step batch) or per-sequence ``(b,)`` lengths (the continuous-
    batching decode path, where every slot sits at its own position).
    """
    b, _, h, hd = q.shape
    smax, n_kv = k_cache.shape[1], k_cache.shape[2]
    g = h // n_kv
    qg = (q[:, 0] / math.sqrt(hd)).reshape(b, n_kv, g, hd)
    s = jnp.einsum("bcgd,bkcd->bcgk", qg, k_cache).astype(jnp.float32)
    pos = jnp.arange(smax, dtype=jnp.int32)
    cl = jnp.asarray(cache_len)
    if cl.ndim:  # per-sequence lengths -> (b, 1, 1, 1) against (..., smax)
        cl = cl.reshape(b, 1, 1, 1)
    s = jnp.where(pos[None, None, None, :] < cl, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bcgk,bkcd->bcgd", p, v_cache)
    return out.reshape(b, 1, h, hd)
