"""Mixture-of-Experts with *stable-sort* token dispatch (the paper inside
the framework).

Dispatch = sort the flat (token, expert-choice) assignment list by expert
id with the co-rank merge sort.  Stability is load-bearing three ways:

1. **Determinism** — equal expert ids keep token order, so training is
   bitwise reproducible across restarts and compilations (a lexicographic
   (expert, token) key would need 64-bit keys; the paper's merge gives the
   same order on 32-bit keys for free).
2. **Fair capacity truncation** — tokens beyond expert capacity are dropped
   *latest-first* (positional order preserved by stability), which is the
   well-defined semantics checked in tests.
3. **Balanced exchange** — the per-expert segments the sort produces are
   contiguous; under expert parallelism the all_to_all slot for each expert
   is exactly its capacity (static shape), the TPU analogue of the paper's
   equal-bytes-per-peer guarantee.

The router supports softmax (DBRX) and sigmoid+bias aux-free scoring
(DeepSeek-V3), plus optional shared experts (V3's 1 shared expert).

Two dispatch semantics, selected by ``moe_apply(dispatch=...)``:

* ``"capacity"`` — the classic fixed-slot scatter above: every expert
  gets ``ceil(T k / E * capacity_factor)`` slots, overflow tokens are
  dropped (earliest-kept), underflow slots burn FLOPs on zeros.
* ``"dropless"`` — the paper's answer: the stable sort already makes
  per-expert segments contiguous, so instead of scattering into slots
  the segments feed *grouped GEMMs* (``lax.ragged_dot``) directly, with
  ``group_sizes`` read off the sorted run.  Zero drops, zero wasted
  slots, at any routing skew — and bit-exact against the dense
  all-experts reference (``moe_dense_reference``) because every
  per-assignment contribution is scattered through *unique* indices and
  reduced over the choice axis in the same order.  The expert-parallel
  (shard_map) form with the same semantics is
  ``repro.distributed.moe.dropless_moe_ffn``.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.exchange import slot_transpose
from repro.models.layers import truncated_normal


def init_moe(
    key,
    d: int,
    ff: int,
    n_experts: int,
    n_shared: int = 0,
    shared_ff: int | None = None,
):
    ks = jax.random.split(key, 5)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(ff)
    p = {
        "router": truncated_normal(ks[0], (d, n_experts), std_in),
        "w_gate": truncated_normal(ks[1], (n_experts, d, ff), std_in),
        "w_up": truncated_normal(ks[2], (n_experts, d, ff), std_in),
        "w_down": truncated_normal(ks[3], (n_experts, ff, d), std_out),
    }
    s = {
        "router": P("data", None),
        "w_gate": P("model", "data", None),  # experts EP-sharded on model
        "w_up": P("model", "data", None),
        "w_down": P("model", None, "data"),
    }
    if n_shared:
        sff = shared_ff or ff * n_shared
        from repro.models.layers import init_mlp

        sp, ss = init_mlp(ks[4], d, sff, kind="swiglu")
        p["shared"], s["shared"] = sp, ss
    return p, s


def _stable_sort_key_val(keys, vals, *, use_merge_sort: bool):
    if use_merge_sort:
        from repro.core.mergesort import sort_key_val

        return sort_key_val(keys, vals)
    order = jnp.argsort(keys, stable=True)
    return keys[order], vals[order]


def route_topk(router_logits, k: int, *, scoring: str = "softmax",
               router_bias=None):
    """Per-token top-k experts + combine weights.

    scoring='softmax' (DBRX): weights = softmax over chosen k.
    scoring='sigmoid' (DeepSeek-V3 aux-free): scores = sigmoid(logits) +
    bias for *selection* only; weights = normalised sigmoid scores.
    """
    if scoring == "sigmoid":
        scores = jax.nn.sigmoid(router_logits.astype(jnp.float32))
        select = scores + (router_bias if router_bias is not None else 0.0)
        _, experts = jax.lax.top_k(select, k)
        w = jnp.take_along_axis(scores, experts, axis=-1)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-20)
    else:
        scores = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
        w, experts = jax.lax.top_k(scores, k)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-20)
    return w, experts


def moe_dispatch(experts, n_experts: int, capacity: int,
                 *, use_merge_sort: bool = True):
    """Stable-sort dispatch plan.

    experts: (T, k) int32 expert choice per token-slot.  Returns
    (slot_token, slot_choice, slot_pos, keep): for each sorted assignment,
    its source token, which of its k choices it was, its position within
    the expert's segment, and whether it fits under ``capacity``.
    Sorted segments are contiguous per expert (ascending), token order
    preserved inside each segment — stability does the bookkeeping.
    """
    t, k = experts.shape
    flat_e = experts.reshape(-1)  # (T*k,) expert ids; index = token*k+choice
    idx = jnp.arange(t * k, dtype=jnp.int32)
    sorted_e, sorted_idx = _stable_sort_key_val(
        flat_e, idx, use_merge_sort=use_merge_sort
    )
    # position within expert segment: rank - first-rank-of-this-expert
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    slot_pos = (jnp.arange(t * k, dtype=jnp.int32) - seg_start.astype(jnp.int32))
    keep = slot_pos < capacity
    slot_token = sorted_idx // k
    slot_choice = sorted_idx % k
    return sorted_e, slot_token, slot_choice, slot_pos, keep


def grouped_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array):
    """``(m, d)`` rows grouped by expert x ``(g, d, f)`` stacked weights
    -> ``(m, f)``: row ``i`` in group ``e`` gets ``x[i] @ w[e]``.

    ``group_sizes`` is ``(g,)`` int32, rows ``[sum(gs[:e]), sum(gs[:e+1]))``
    belong to group ``e``; rows beyond ``sum(gs)`` produce zeros (so
    exchange-slot padding is inert).  Uses ``lax.ragged_dot`` — one GEMM,
    no per-expert slot padding — with a dense all-groups einsum fallback
    for backends without the primitive.
    """
    group_sizes = jnp.asarray(group_sizes, jnp.int32)
    if hasattr(jax.lax, "ragged_dot"):
        return jax.lax.ragged_dot(x, w, group_sizes)
    m = x.shape[0]
    ends = jnp.cumsum(group_sizes)
    gid = jnp.searchsorted(ends, jnp.arange(m, dtype=jnp.int32), side="right")
    dense = jnp.einsum("md,gdf->mgf", x, w)
    out = jnp.take_along_axis(
        dense, jnp.clip(gid, 0, w.shape[0] - 1)[:, None, None], axis=1
    )[:, 0]
    return jnp.where((jnp.arange(m) < ends[-1])[:, None], out, 0)


def moe_dispatch_dropless(experts, n_experts: int,
                          *, use_merge_sort: bool = True):
    """Exact-cut dispatch plan: no capacity, no ``keep`` mask.

    Returns ``(sorted_e, sorted_idx, group_sizes)``: the stable-sorted
    expert ids, each sorted slot's flat assignment index
    (``token * k + choice``), and the per-expert segment sizes
    (``group_sizes.sum() == T * k`` — every assignment is dispatched,
    which *is* the dropless property).
    """
    t, k = experts.shape
    flat_e = experts.reshape(-1).astype(jnp.int32)
    idx = jnp.arange(t * k, dtype=jnp.int32)
    sorted_e, sorted_idx = _stable_sort_key_val(
        flat_e, idx, use_merge_sort=use_merge_sort
    )
    bounds = jnp.searchsorted(
        sorted_e, jnp.arange(n_experts + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    return sorted_e, sorted_idx, bounds[1:] - bounds[:-1]


def _dropless_moe(params, xt, w, experts, n_experts, top_k, use_merge_sort):
    """Grouped-GEMM expert FFN over the exact sorted segments.

    The combine scatters each assignment's weighted output through the
    *unique* indices ``sorted_idx`` (a permutation of ``arange(T k)``)
    and reduces over the choice axis — the identical reduction order as
    ``moe_dense_reference``, so the two are bit-exact, not just close.
    """
    t, d = xt.shape
    _, sorted_idx, group_sizes = moe_dispatch_dropless(
        experts, n_experts, use_merge_sort=use_merge_sort
    )
    xs = xt[sorted_idx // top_k]  # (T*k, d) rows in expert order
    gate = grouped_gemm(xs, params["w_gate"].astype(xt.dtype), group_sizes)
    up = grouped_gemm(xs, params["w_up"].astype(xt.dtype), group_sizes)
    h = jax.nn.silu(gate) * up
    ys = grouped_gemm(h, params["w_down"].astype(xt.dtype), group_sizes)
    token_w = w.reshape(-1)[sorted_idx].astype(xt.dtype)
    out = jnp.zeros((t * top_k, d), xt.dtype)
    out = out.at[sorted_idx].set(ys * token_w[:, None])
    return out.reshape(t, top_k, d).sum(axis=1)


def moe_dense_reference(params, x, *, n_experts: int, top_k: int,
                        scoring: str = "softmax"):
    """All-experts dense reference: every expert runs every token.

    The ground truth the dropless path is asserted bit-exact against —
    written for obviousness (a Python loop of plain matmuls), not speed.
    Per-(token, choice) contributions are stacked ``(T, k, d)`` and
    summed over the choice axis, the same reduction order as both
    dispatch paths.
    """
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt, params["router"].astype(x.dtype))
    w, experts = route_topk(logits, top_k, scoring=scoring)
    ys = []
    for e in range(n_experts):
        g = xt @ params["w_gate"][e].astype(x.dtype)
        u = xt @ params["w_up"][e].astype(x.dtype)
        ys.append((jax.nn.silu(g) * u) @ params["w_down"][e].astype(x.dtype))
    ys = jnp.stack(ys)  # (E, T, d)
    t = xt.shape[0]
    contrib = jnp.stack(
        [
            ys[experts[:, c], jnp.arange(t)]
            * w[:, c, None].astype(x.dtype)
            for c in range(top_k)
        ],
        axis=1,
    )  # (T, k, d)
    out = contrib.sum(axis=1)
    if "shared" in params:
        from repro.models.layers import mlp

        out = out + mlp(params["shared"], x, kind="swiglu").reshape(t, d)
    return out.reshape(b, s, d)


def _dispatch_combine_one_group(xt, w, experts, n_experts, top_k, capacity,
                                use_merge_sort):
    """Dispatch tokens of one group into (E, C, d) slots and return
    (ex_in, combine_fn).  Stable sort gives expert-contiguous segments and
    positional (earliest-kept) capacity truncation."""
    t, d = xt.shape
    sorted_e, slot_token, slot_choice, slot_pos, keep = moe_dispatch(
        experts, n_experts, capacity, use_merge_sort=use_merge_sort
    )
    flat_slot = sorted_e.astype(jnp.int32) * capacity + slot_pos
    flat_slot = jnp.where(keep, flat_slot, n_experts * capacity)  # OOB drop
    ex_in = jnp.zeros((n_experts * capacity, d), xt.dtype)
    ex_in = ex_in.at[flat_slot].set(xt[slot_token], mode="drop")
    ex_in = ex_in.reshape(n_experts, capacity, d)

    def combine(ex_out):
        flat_out = ex_out.reshape(n_experts * capacity, d)
        token_w = w.reshape(-1)[slot_token * top_k + slot_choice]
        contrib = jnp.where(
            keep[:, None],
            flat_out[jnp.clip(flat_slot, 0, n_experts * capacity - 1)]
            * token_w[:, None].astype(xt.dtype),
            0.0,
        )
        return jnp.zeros((t, d), xt.dtype).at[slot_token].add(contrib)

    return ex_in, combine


def moe_apply(params, x, *, n_experts: int, top_k: int, capacity_factor: float,
              scoring: str = "softmax", use_merge_sort: bool = True,
              dispatch_groups: int = 1, dispatch: str = "capacity",
              dtype=jnp.bfloat16):
    """Full MoE layer on (b, s, d) activations.

    ``dispatch`` selects the token-dispatch semantics:
    ``"capacity"`` — fixed ``capacity_factor`` slots, overflow dropped;
    ``"dropless"`` — exact-cut grouped GEMMs, zero drops and zero wasted
    slots (``capacity_factor`` and ``dispatch_groups`` are capacity-path
    knobs and are ignored — there are no slots to size or localise).

    ``dispatch_groups > 1`` is GShard-style local dispatch: tokens are
    split into G groups (sized to the data-parallel shards), each group
    sorts and fills a *local* capacity slice, so the dispatch scatter is
    shard-local and the only cross-device movement is the (group <-> expert)
    all_to_all that EP requires anyway.  Capacity is per group.
    """
    from repro.models import layers as L

    if dispatch not in ("capacity", "dropless"):
        raise ValueError(
            f"moe_apply: unknown dispatch {dispatch!r} "
            "(expected 'capacity' or 'dropless')"
        )
    b, s, d = x.shape
    t = b * s
    g = max(1, min(dispatch_groups, t))
    while t % g:
        g -= 1
    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt, params["router"].astype(x.dtype))
    w, experts = route_topk(logits, top_k, scoring=scoring)

    if dispatch == "dropless":
        out = _dropless_moe(
            params, xt, w, experts, n_experts, top_k, use_merge_sort
        )
        if "shared" in params:
            from repro.models.layers import mlp

            out = out + mlp(params["shared"], x, kind="swiglu").reshape(t, d)
        return out.reshape(b, s, d)

    tg = t // g
    capacity = int(math.ceil(tg * top_k / n_experts * capacity_factor))
    capacity = max(capacity, top_k)

    if g == 1:
        ex_in, combine = _dispatch_combine_one_group(
            xt, w, experts, n_experts, top_k, capacity, use_merge_sort
        )
        gate = jnp.einsum("ecd,edf->ecf", ex_in, params["w_gate"].astype(x.dtype))
        up = jnp.einsum("ecd,edf->ecf", ex_in, params["w_up"].astype(x.dtype))
        h = jax.nn.silu(gate) * up
        ex_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))
        out = combine(ex_out)
    else:
        xg = xt.reshape(g, tg, d)
        wg = w.reshape(g, tg, top_k)
        eg = experts.reshape(g, tg, top_k)

        ex_in = jax.vmap(
            lambda a, b_, c: _dispatch_combine_one_group(
                a, b_, c, n_experts, top_k, capacity, use_merge_sort
            )[0]
        )(xg, wg, eg)  # (G, E, Cg, d)
        # group dim lives on the batch axes; expert dim on the EP axis —
        # the slot transpose IS the balanced all_to_all (equal bytes per
        # peer because capacity is static), shared with the exchange
        # subsystem's sort path.
        ba = L.get_batch_axes()
        constrain = L.constrain_spec if ba is not None else None
        ex_g = slot_transpose(  # (E, G, Cg, d)
            ex_in,
            constrain=constrain,
            in_spec=(ba, None, None, None),
            out_spec=("model", ba, None, None),
        )
        gate = jnp.einsum("egcd,edf->egcf", ex_g, params["w_gate"].astype(x.dtype))
        up = jnp.einsum("egcd,edf->egcf", ex_g, params["w_up"].astype(x.dtype))
        h = jax.nn.silu(gate) * up
        ex_out = jnp.einsum("egcf,efd->egcd", h, params["w_down"].astype(x.dtype))
        ex_out = slot_transpose(  # (G, E, Cg, d)
            ex_out,
            constrain=constrain,
            in_spec=("model", ba, None, None),
            out_spec=(ba, None, None, None),
        )

        # re-run dispatch bookkeeping per group to combine (cheap ints)
        def one_combine(xt_g, w_g, e_g, exo_g):
            _, combine = _dispatch_combine_one_group(
                xt_g, w_g, e_g, n_experts, top_k, capacity, use_merge_sort
            )
            return combine(exo_g)

        out = jax.vmap(one_combine)(xg, wg, eg, ex_out).reshape(t, d)

    if "shared" in params:
        from repro.models.layers import mlp

        out = out + mlp(params["shared"], x, kind="swiglu").reshape(t, d)
    return out.reshape(b, s, d)


def load_balance_loss(router_logits, experts, n_experts: int):
    """Switch-style auxiliary load-balance loss (off for sigmoid/aux-free)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(experts[:, 0], n_experts)
    ce = jnp.mean(one_hot, axis=0)
    return n_experts * jnp.sum(me * ce)
