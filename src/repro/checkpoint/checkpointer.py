"""Sharded, atomic, mesh-agnostic checkpointing.

* Each leaf is saved as an ``.npy`` under ``step_XXXXXXXX.tmp/``; the
  directory is fsynced and atomically renamed to ``step_XXXXXXXX`` —
  a torn write can never be mistaken for a complete checkpoint.
* A ``manifest.json`` stores the flattened tree structure and each leaf's
  logical PartitionSpec, so restore re-shards onto *any* mesh whose axis
  names match (elastic shrink/grow across restarts; DESIGN.md §8).
* ``latest_step`` scans for the newest complete checkpoint — the restart
  loop in ``launch/train.py`` uses it after any failure.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["__".join(str(p) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def _spec_to_json(spec: P):
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            out.append(list(entry))
        else:
            out.append(entry)
    return out


def _spec_from_json(entries):
    def one(e):
        if e is None:
            return None
        if isinstance(e, list):
            return tuple(e)
        return e

    return P(*(one(e) for e in entries))


def save_checkpoint(ckpt_dir: str, step: int, state, specs=None):
    """Atomically save a pytree (+ optional PartitionSpec tree)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, leaves, _ = _flatten_with_paths(state)
    if specs is not None:
        snames, sleaves, _ = _flatten_with_paths(specs)
        spec_map = dict(zip(snames, sleaves))
    else:
        spec_map = {}

    manifest = {"step": step, "leaves": []}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            # ml_dtypes (bf16/fp8) round-trip through npy as raw bits
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        fn = f"{abs(hash(name)) % 10**10}_{len(manifest['leaves'])}.npy"
        np.save(os.path.join(tmp, fn), arr)
        entry = {"name": name, "file": fn, "dtype": logical_dtype,
                 "shape": list(arr.shape)}
        if name in spec_map and isinstance(spec_map[name], P):
            entry["spec"] = _spec_to_json(spec_map[name])
        manifest["leaves"].append(entry)

    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like, mesh=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  With ``mesh``, leaves are placed with their saved
    logical spec resolved on the *current* mesh — elastic re-sharding."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {e["name"]: e for e in manifest["leaves"]}

    names, leaves, treedef = _flatten_with_paths(like)
    out = []
    for name, leaf in zip(names, leaves):
        entry = by_name[name]
        arr = np.load(os.path.join(path, entry["file"]))
        if str(arr.dtype) != entry["dtype"]:
            import ml_dtypes

            arr = arr.view(np.dtype(getattr(ml_dtypes, entry["dtype"])))
        if mesh is not None and "spec" in entry:
            from repro.launch.sharding import resolve_spec

            spec = resolve_spec(_spec_from_json(entry["spec"]), mesh)
            arr = jax.device_put(arr, NamedSharding(mesh, spec))
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
