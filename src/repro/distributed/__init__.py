"""Sharded exchange subsystem: the paper's merge across the mesh.

The layer between the single-device k-way merge (``repro.core.kway``)
and the device mesh.  Three modules:

* ``splitters`` — exact global splitters: pairwise and k-way co-rank
  searches executed over collectives, ``O(p^2)`` scalars per lock-step
  round, never gathering run data.
* ``exchange`` — the balanced ``all_to_all`` that ships each device
  exactly its ``N/p``-element output block (static capacity slots +
  lengths sideband), and the jit-level ``slot_transpose`` shared with
  MoE expert-parallel dispatch.
* ``api`` — ``sharded_sort`` / ``sharded_merge_kway`` /
  ``distributed_merge`` with the ``strategy=`` switch
  (``allgather | corank | exchange``) and the host-level padding
  wrapper.  See ``api``'s docstring for the memory/traffic trade-offs.
"""

from repro.distributed.api import (
    distributed_merge,
    distributed_merge_corank,
    distributed_sort,
    sharded_merge_kway,
    sharded_sort,
    sharded_sort_host,
)
from repro.distributed.exchange import (
    exchange_block,
    sentinel_max,
    slot_transpose,
    window,
)
from repro.distributed.splitters import (
    distributed_co_rank,
    distributed_co_rank_kway,
)

__all__ = [
    "distributed_merge",
    "distributed_merge_corank",
    "distributed_sort",
    "sharded_merge_kway",
    "sharded_sort",
    "sharded_sort_host",
    "exchange_block",
    "slot_transpose",
    "sentinel_max",
    "window",
    "distributed_co_rank",
    "distributed_co_rank_kway",
]
