"""Sharded exchange subsystem: the paper's merge across the mesh.

The layer between the single-device k-way merge (``repro.core.kway``)
and the device mesh.  Four modules:

* ``splitters`` — exact global splitters: pairwise and k-way co-rank
  searches executed over collectives, ``O(p^2)`` scalars per lock-step
  round, never gathering run data.
* ``exchange`` — ``balanced_exchange``, the ragged slot ``all_to_all``
  with an exact lengths sideband that ships each device exactly its
  segments; ``slot_transpose`` (jit-level MoE capacity dispatch) is its
  static-shape special case.
* ``moe`` — dropless expert-parallel dispatch: stable sort by expert
  id + ``distributed_segment_cuts`` + ``balanced_exchange`` + grouped
  GEMMs, zero drops and zero wasted slots at any routing skew.
* ``api`` — ``sharded_sort`` / ``sharded_merge_kway`` /
  ``distributed_merge`` with the ``strategy=`` switch
  (``allgather | corank | exchange``) and the host-level padding
  wrapper.  See ``api``'s docstring for the memory/traffic trade-offs.
"""

from repro.distributed.api import (
    distributed_merge,
    distributed_merge_corank,
    distributed_sort,
    sharded_merge_kway,
    sharded_sort,
    sharded_sort_host,
)
from repro.distributed.exchange import (
    balanced_exchange,
    exchange_block,
    sentinel_max,
    slot_transpose,
    window,
    window_rows,
)
from repro.distributed.splitters import (
    distributed_co_rank,
    distributed_co_rank_kway,
    distributed_segment_cuts,
)
from repro.distributed.moe import (
    DroplessPlan,
    dropless_combine,
    dropless_dispatch,
    dropless_moe_ffn,
)

__all__ = [
    "distributed_merge",
    "distributed_merge_corank",
    "distributed_sort",
    "sharded_merge_kway",
    "sharded_sort",
    "sharded_sort_host",
    "balanced_exchange",
    "exchange_block",
    "slot_transpose",
    "sentinel_max",
    "window",
    "window_rows",
    "distributed_co_rank",
    "distributed_co_rank_kway",
    "distributed_segment_cuts",
    "DroplessPlan",
    "dropless_combine",
    "dropless_dispatch",
    "dropless_moe_ffn",
]
