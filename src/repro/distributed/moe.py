"""Dropless expert-parallel MoE dispatch on exact segment cuts.

The capacity-factor dispatch in ``repro.models.moe`` over-provisions:
every expert gets a fixed ``ceil(T k / E * f)`` slot block and tokens
beyond it are dropped — correctness traded for static shapes twice over
(wasted slots *and* lost tokens, both worst at exactly the routing skew
MoE training produces).  The paper's co-rank machinery removes the
trade: the stable sort by expert id makes per-expert segments
contiguous, ``distributed_segment_cuts`` resolves every global segment
boundary in one ``O(p E)``-scalar collective round, and the ragged
``balanced_exchange`` ships exactly those segments with a lengths
sideband.  No token is dropped and no slot is wasted at *any* skew.

Shapes are still static — that is non-negotiable under SPMD — so the
exchange ships ``(p, capacity)`` slots.  ``capacity=None`` defaults to
the worst-case-safe local assignment count ``n = t_loc * top_k`` (all of
a device's tokens routed to one peer's experts), which guarantees zero
drops unconditionally; an explicit smaller ``capacity`` trades memory
for *accounted* truncation: the cut matrix says exactly how many
assignments each peer planned to send, the sideband says how many
arrived, and the difference is the drop count — detected, never silent.
The slot tail is padding on the wire only; the grouped GEMM's
``group_sizes`` stop at the real rows, so no FLOPs are wasted on it.

Pipeline (each device, inside ``shard_map`` over ``axis_name``):

1. stable-sort the flat ``(t_loc * k,)`` expert ids (merge sort — ties
   keep token order, so the whole pipeline is deterministic);
2. ``distributed_segment_cuts`` → the replicated ``(p, E + 1)`` cut
   matrix = the complete send/receive schedule;
3. slice my run at the expert-ownership boundaries (expert ``e`` lives
   on device ``e // ceil(E/p)``) and ``balanced_exchange`` the segments
   with their lengths sideband;
4. ``merge_kway_ranked`` the ``p`` received sorted runs — device order
   is the stable tie-break, so the grouped rows are the *globally*
   stable (expert, device, position) order;
5. grouped GEMMs over the merged rows with per-expert ``group_sizes``
   computed from the received runs (exact under truncation);
6. combine: reverse the exchange (the slot ``all_to_all`` is an
   involution), gather each assignment's result from its
   ``(owner, position)`` slot, weight, and scatter-add back to tokens
   through the *unique* sorted-assignment indices — the same reduction
   order as a dense reference, hence bit-exact against it.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs
from repro.core import engine
from repro.core.compat import axis_size as _axis_size
from repro.core.kway import merge_kway_ranked
from repro.distributed.exchange import balanced_exchange, window, window_rows
from repro.distributed.splitters import distributed_segment_cuts

__all__ = [
    "DroplessPlan",
    "dropless_dispatch",
    "dropless_combine",
    "dropless_moe_ffn",
]


class DroplessPlan(NamedTuple):
    """Everything ``dropless_combine`` and the drop accounting need.

    ``xg``/``group_sizes`` feed the grouped GEMMs; the rest reverses the
    exchange.  ``planned - recv_lengths`` (both per source device) is the
    exact per-peer drop count — zero when ``capacity`` was ``None``.
    """

    xg: jax.Array  # (p * cap, d) rows grouped by owned expert
    group_sizes: jax.Array  # (e_per,) rows per owned expert (sum = real rows)
    perm: jax.Array  # (p * cap,) merged position -> recv slot row
    valid: jax.Array  # (p * cap,) bool, real (non-padding) merged rows
    recv_lengths: jax.Array  # (p,) real rows received per source device
    planned: jax.Array  # (p,) rows each source planned to send me (cuts)
    send_lo: jax.Array  # (p,) my sorted run's segment start per peer
    send_lengths: jax.Array  # (p,) segment lengths actually sent (clipped)
    sorted_e: jax.Array  # (n,) my expert ids, stable-sorted
    sorted_idx: jax.Array  # (n,) my assignment index (token * k + choice)


def _expert_ownership(n_experts: int, p: int):
    """Static contiguous expert -> device map: ``e_per = ceil(E/p)``
    experts per device, boundaries clipped to ``E`` (trailing devices may
    own fewer, never zero GEMM groups — ``group_sizes`` handles it)."""
    e_per = -(-n_experts // p)
    owner_bounds = jnp.minimum(
        jnp.arange(p + 1, dtype=jnp.int32) * e_per, n_experts
    )
    return e_per, owner_bounds


def dropless_dispatch(
    xt: jax.Array,
    experts: jax.Array,
    n_experts: int,
    axis_name: str,
    capacity: int | None = None,
    *,
    use_merge_sort: bool = True,
) -> DroplessPlan:
    """Exact-cut dispatch of this device's tokens to expert owners.

    Call inside ``shard_map``.  ``xt`` is ``(t_loc, d)`` local tokens,
    ``experts`` ``(t_loc, k)`` routing choices.  Returns a
    :class:`DroplessPlan` whose ``xg`` rows are this device's *received*
    assignments grouped by owned expert, ready for grouped GEMMs with
    ``group_sizes``.

    ``capacity=None`` uses the worst-case-safe per-peer slot
    ``n = t_loc * k`` (zero drops at any skew); smaller values truncate
    each (sender, owner) segment earliest-kept, with the exact overflow
    visible as ``plan.planned - plan.recv_lengths``.
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    t, k = experts.shape
    n = t * k
    d = xt.shape[-1]
    cap = n if capacity is None else int(capacity)

    flat_e = experts.reshape(-1).astype(jnp.int32)
    idx = jnp.arange(n, dtype=jnp.int32)
    if use_merge_sort:
        from repro.core.mergesort import sort_key_val

        sorted_e, sorted_idx = sort_key_val(flat_e, idx)
    else:
        order = jnp.argsort(flat_e, stable=True)
        sorted_e, sorted_idx = flat_e[order], idx[order]
    xs = xt[sorted_idx // k]  # (n, d) rows in expert order

    # The complete schedule: one collective round of O(p * E) scalars.
    cuts = distributed_segment_cuts(sorted_e, n_experts, axis_name)
    e_per, owner_bounds = _expert_ownership(n_experts, p)
    my_cuts = cuts[r]
    send_lo = my_cuts[owner_bounds[:-1]]  # (p,)
    send_hi = my_cuts[owner_bounds[1:]]
    send_lengths = jnp.minimum(send_hi - send_lo, cap)

    send_x = jax.vmap(lambda a, b: window_rows(xs, a, b, cap))(
        send_lo, send_hi
    )  # (p, cap, d)
    send_e = jax.vmap(lambda a, b: window(sorted_e, a, b, cap))(
        send_lo, send_hi
    )  # (p, cap), sentinel tails keep rows sorted
    recv_x, recv_lengths = balanced_exchange(
        send_x, send_lengths, axis_name=axis_name
    )
    recv_e, _ = balanced_exchange(send_e, axis_name=axis_name)

    # What each source *planned* to send me (from the replicated cuts) —
    # the drop accounting, exact by construction.
    lob, hib = owner_bounds[r], owner_bounds[r + 1]
    planned = cuts[:, hib] - cuts[:, lob]  # (p,)

    # Merge the p received sorted runs; device order = stable tie-break,
    # so the merged order is the globally stable (expert, dev, pos) order.
    row_ids = jnp.arange(p * cap, dtype=jnp.int32).reshape(p, cap)
    _, perm = merge_kway_ranked(
        recv_e, vals=row_ids, lengths=recv_lengths, out_len=p * cap
    )
    total = recv_lengths.sum()
    valid = jnp.arange(p * cap, dtype=jnp.int32) < total
    xg = jnp.where(
        valid[:, None],
        recv_x.reshape(p * cap, d)[perm],
        jnp.zeros((), xt.dtype),
    )

    # Per-owned-expert group sizes from the RECEIVED rows (clipped by the
    # sideband so sentinel padding never counts) — exact even when a
    # small capacity truncated some segment.
    seg_vals = lob + jnp.arange(e_per + 1, dtype=jnp.int32)
    rl = jax.vmap(
        lambda row, ln: engine.value_cut_counts(row, seg_vals, ln)
    )(recv_e, recv_lengths)  # (p, e_per + 1)
    group_sizes = (rl[:, 1:] - rl[:, :-1]).sum(axis=0)  # (e_per,)

    if obs.enabled():
        obs.gauge(
            "moe.planned_per_source", planned, capacity=cap, device=r
        )
        obs.gauge("moe.recv_per_source", recv_lengths, device=r)
        # Exact overflow accounting: planned minus arrived, summed — zero
        # at the worst-case-safe default capacity, never silent otherwise.
        obs.counter(
            "moe.overflow",
            (planned - recv_lengths).sum(),
            capacity=cap,
            device=r,
        )
        obs.gauge(
            "moe.group_sizes", group_sizes, n_experts=n_experts, device=r
        )
        mean = jnp.maximum(
            group_sizes.sum().astype(jnp.float32) / e_per, 1e-9
        )
        obs.gauge(
            "moe.routing_skew",
            group_sizes.max().astype(jnp.float32) / mean,
            device=r,
        )

    return DroplessPlan(
        xg=xg,
        group_sizes=group_sizes,
        perm=perm,
        valid=valid,
        recv_lengths=recv_lengths,
        planned=planned,
        send_lo=send_lo,
        send_lengths=send_lengths,
        sorted_e=sorted_e,
        sorted_idx=sorted_idx,
    )


def dropless_combine(
    ys: jax.Array,
    w: jax.Array,
    plan: DroplessPlan,
    axis_name: str,
    top_k: int,
) -> jax.Array:
    """Return expert outputs to their source tokens and combine.

    ``ys`` is ``(p * cap, d)`` aligned with ``plan.xg`` rows; ``w`` is
    this device's ``(t_loc, top_k)`` combine weights.  The reverse
    exchange is the same slot ``all_to_all`` applied again (an
    involution), so each assignment's result lands back at its
    ``(owner, position)`` slot; dropped assignments (position beyond the
    sent length) contribute zero.  The final scatter uses the *unique*
    sorted-assignment indices followed by a sum over the choice axis —
    the same reduction order as a dense reference, hence bit-exact.
    """
    p = plan.recv_lengths.shape[0]
    n = plan.sorted_e.shape[0]
    cap = plan.perm.shape[0] // p
    d = ys.shape[-1]

    # Un-merge to received-slot layout, then reverse the exchange.
    back = jnp.zeros((p * cap, d), ys.dtype)
    back = back.at[jnp.where(plan.valid, plan.perm, p * cap)].set(
        ys, mode="drop"
    )
    ret, _ = balanced_exchange(back.reshape(p, cap, d), axis_name=axis_name)
    # ret[q] = results for the segment I originally sent to peer q.

    # owner of each sorted assignment, from its expert id (the static
    # contiguous ownership map: e_per experts per device)
    e_per = plan.group_sizes.shape[0]
    owner = jnp.clip(plan.sorted_e // e_per, 0, p - 1)
    pos = jnp.arange(n, dtype=jnp.int32) - plan.send_lo[owner]
    kept = pos < plan.send_lengths[owner]
    res = jnp.where(
        kept[:, None],
        ret.reshape(p * cap, d)[
            owner * cap + jnp.clip(pos, 0, cap - 1)
        ],
        jnp.zeros((), ys.dtype),
    )  # (n, d) per sorted assignment

    token_w = w.reshape(-1)[plan.sorted_idx].astype(ys.dtype)
    contrib = res * token_w[:, None]
    out = jnp.zeros((n, d), ys.dtype).at[plan.sorted_idx].set(contrib)
    return out.reshape(n // top_k, top_k, d).sum(axis=1)  # (t_loc, d)


def dropless_moe_ffn(
    xt: jax.Array,
    experts: jax.Array,
    w: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    n_experts: int,
    axis_name: str,
    capacity: int | None = None,
    *,
    use_merge_sort: bool = True,
):
    """Full dropless expert-parallel FFN for one device's tokens.

    Call inside ``shard_map``; the weight arguments are this device's
    *owned* shards ``(e_per, d, ff)`` / ``(e_per, ff, d)``.  Returns
    ``(out, plan)`` — ``out`` is ``(t_loc, d)``; ``plan`` carries the
    exact drop accounting (all zeros for ``capacity=None``).
    """
    from repro.models.moe import grouped_gemm

    with obs.span("repro.dropless_moe_ffn"):
        with obs.span("repro.dropless_dispatch"):
            plan = dropless_dispatch(
                xt,
                experts,
                n_experts,
                axis_name,
                capacity,
                use_merge_sort=use_merge_sort,
            )
        with obs.span("repro.moe_grouped_gemm"):
            gate = grouped_gemm(plan.xg, w_gate, plan.group_sizes)
            up = grouped_gemm(plan.xg, w_up, plan.group_sizes)
            h = jax.nn.silu(gate) * up
            ys = grouped_gemm(h, w_down, plan.group_sizes)
        with obs.span("repro.dropless_combine"):
            out = dropless_combine(ys, w, plan, axis_name, experts.shape[-1])
    return out, plan
