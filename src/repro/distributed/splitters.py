"""Distributed co-ranking: exact global splitters over collectives.

The paper's central object — the co-rank of an output rank ``i`` — is a
pure *search*, so it distributes without moving any run data: every
remote probe is a value lookup or a ``searchsorted`` count that the run's
owner can answer locally, and the ``p`` devices' searches advance in
lock-step rounds of ``O(p^2)``-scalar collectives.

All three searches here are instantiations of the one co-rank engine
(``repro.core.engine``) with *remote* reads — the search bodies, the
Lemma-1 tie-break and the round bounds are the engine's; this module
only supplies the collective read/count/reduce plumbing:

* ``distributed_co_rank`` — the pairwise Algorithm 1
  (``engine.co_rank_pairwise``) with each of its four boundary reads
  answered by :func:`_remote_read` (publish indices via ``all_gather``,
  owners answer via masked ``psum``).  Run to the engine's static
  ``pairwise_lockstep_rounds`` schedule so all ``p`` searches share
  collective rounds.

* ``distributed_co_rank_kway`` — the k-way bisection
  (``engine.co_rank_search``) through :class:`_CollectiveProbe`: ``p``
  sorted runs, one per device, a *batch* of ``B`` output ranks per
  device, all ``p * B`` cut-vector searches resolving together in the
  engine's ``kway_round_bound(w)`` lock-step rounds.  Per round each
  device publishes its ``(B, p)`` candidate indices (one
  ``all_gather``), answers value lookups into its own run (one masked
  ``psum``), and contributes its Lemma-1 tie-aware ``searchsorted``
  counts for every candidate value (one more ``psum``) — ``O(p^2 B)``
  scalars per round, never a single element of run data gathered.

* ``distributed_segment_cuts`` — the *value-keyed* degenerate case that
  MoE expert dispatch needs: when the boundary **values** are known a
  priori (segment ids ``0..E-1``), the bisection collapses to the
  engine's ``value_cut_counts`` (one local ``searchsorted`` per
  boundary, the same strict Lemma-1 side), so all ``E + 1`` global
  segment boundaries resolve in a **single** collective round of
  ``O(p * E)`` int32 scalars.  The result agrees column-for-column with
  ``distributed_co_rank_kway`` evaluated at the boundary *ranks*
  (verified in ``tests/_moe_dropless_check.py``): every element with key
  ``< e`` precedes every element with key ``>= e`` in the stable merge,
  so the rank-``b_e`` cut vector is exactly the per-run ``< e`` counts.

All return the same cuts as their single-device counterparts
(``repro.core.corank.co_rank`` / ``repro.core.kway.co_rank_kway``),
verified element-for-element in ``tests/_exchange_check.py`` and the
cross-layer sweep in ``tests/test_engine.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs
from repro.core import engine
from repro.core.compat import axis_size as _axis_size
from repro.core.engine import SIDE_STRICT, SIDE_TIES

__all__ = [
    "distributed_co_rank",
    "distributed_co_rank_kway",
    "distributed_segment_cuts",
]


# ---------------------------------------------------------------------------
# pairwise (Algorithm 1 over collectives)
# ---------------------------------------------------------------------------


def _remote_read(shard: jax.Array, gidx: jax.Array, axis_name: str):
    """Every device reads global element ``gidx`` (its own request) from the
    sharded array: publish indices, owners answer via masked psum.

    The engine clamps ``gidx`` into the global range before calling;
    owner/local clamping here guards the uniform-shard arithmetic.
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    sz = shard.shape[0]  # local shard size (uniform)
    wanted = lax.all_gather(gidx, axis_name)  # (p,) every device's request
    owner = jnp.clip(wanted // sz, 0, p - 1)
    local = jnp.where(owner == r, wanted - r * sz, 0)
    vals = shard[jnp.clip(local, 0, sz - 1)]  # (p,) my answers
    answers = lax.psum(
        jnp.where(owner == r, vals, jnp.zeros_like(vals)), axis_name
    )
    return answers[r]


def distributed_co_rank(
    i: jax.Array, a_shard: jax.Array, b_shard: jax.Array, axis_name: str
):
    """Algorithm 1 with remote reads over collectives (per-device rank i).

    Each device searches for the co-ranks of its own ``i``; the p searches
    run in lock-step rounds (the engine's static
    ``pairwise_lockstep_rounds`` schedule, so converged searches no-op
    while the collectives stay aligned).  Returns ``(j, k)`` global
    co-ranks.
    """
    p = _axis_size(axis_name)
    m = a_shard.shape[0] * p
    n = b_shard.shape[0] * p
    j, k, _ = engine.co_rank_pairwise(
        i,
        m,
        n,
        read_a=lambda idx: _remote_read(a_shard, idx, axis_name),
        read_b=lambda idx: _remote_read(b_shard, idx, axis_name),
        rounds=engine.pairwise_lockstep_rounds(m, n),
        metric="splitters.pairwise_rounds",
        labels={"device": lax.axis_index(axis_name)},
    )
    return j, k


# ---------------------------------------------------------------------------
# k-way (one sorted run per device, batched ranks)
# ---------------------------------------------------------------------------


class _CollectiveProbe:
    """Engine probe over one sorted run per mesh device.

    ``values`` publishes every device's ``(B, p)`` candidate indices
    (``all_gather``) and resolves them with a masked ``psum`` (owners
    answer); ``counts`` is this device's local ``searchsorted`` of every
    candidate value into its own run, both Lemma-1 sides; ``reduce``
    ``psum``s the per-owner contributions and keeps this device's own
    ``(B, p)`` searches.  No run element ever leaves its device.
    """

    xp = jnp
    run_loop = staticmethod(engine.run_fori)

    def __init__(self, run_shard: jax.Array, axis_name: str, lengths, batch):
        self._run = run_shard
        self._axis = axis_name
        self._p = _axis_size(axis_name)
        self._r = lax.axis_index(axis_name)
        self._b = batch
        self._run_ids = jnp.arange(self._p, dtype=jnp.int32)
        self.width = run_shard.shape[0]
        self._lengths = lengths  # (p,) global per-run lengths
        self.lengths = lengths[None, :]  # broadcast vs the (B, p) cuts
        self.owner_ids = self._r  # I own only my run's counts
        self.query_ids = self._run_ids[None, None, :]
        self.owner_lengths = lengths[self._r]

    def init_bounds(self, i):
        # + i*0 keeps shard_map's varying-axes type aligned with the body
        # (i is per-device inside shard_map).
        lo = jnp.zeros((self._b, self._p), jnp.int32) + i * 0
        hi = jnp.broadcast_to(self.lengths, (self._b, self._p)) + i * 0
        return lo, hi

    def values(self, t):
        # Publish every device's candidate indices: (p, B, p); entry
        # [d, q, rp] is device d's probe into run rp for its rank i[q].
        cand = lax.all_gather(t, self._axis)
        # Owners answer the value lookups: my column rp == r.
        mine = self._run[jnp.clip(cand[:, :, self._r], 0, self.width - 1)]
        return lax.psum(
            jnp.where(
                self._run_ids[None, None, :] == self._r,
                mine[:, :, None],
                jnp.zeros((), self._run.dtype),
            ),
            self._axis,
        )  # (p, B, p): vals[d, q, rp] = run_rp[cand[d, q, rp]]

    def counts(self, x):
        # My Lemma-1 count contribution for every candidate value (the
        # tie-break side is selected by the engine against owner_ids).
        flat = x.reshape(-1)
        le = jnp.searchsorted(self._run, flat, side=SIDE_TIES)
        lt = jnp.searchsorted(self._run, flat, side=SIDE_STRICT)
        shape = (self._p, self._b, self._p)
        return (
            le.astype(jnp.int32).reshape(shape),
            lt.astype(jnp.int32).reshape(shape),
        )

    def reduce(self, cnt):
        return lax.psum(cnt, self._axis)[self._r]  # (B, p) — my searches


def distributed_co_rank_kway(
    i: jax.Array,
    run_shard: jax.Array,
    axis_name: str,
    length: jax.Array | None = None,
) -> jax.Array:
    """Cut matrices of output ranks ``i`` into the mesh's ``p`` sorted runs.

    Call inside ``shard_map``.  Device ``r`` holds ``run_shard`` — sorted
    run ``r`` of the global k-way merge (``k = p``), width ``w`` — and
    asks for the cut vectors of *its own* ``B`` output ranks ``i``.

    Args:
      i: ``(B,)`` output ranks of this device (``B`` static, uniform).
      run_shard: ``(w,)`` this device's sorted run.  Ragged runs must be
        padded with row-maximal values and declare ``length``.
      axis_name: mesh axis the runs are sharded over.
      length: optional scalar count of real elements in ``run_shard``.

    Returns:
      int32 ``(B, p)``: row ``b`` is the cut vector of rank ``i[b]`` —
      ``out[b].sum() == min(i[b], total)`` and the stable k-way merge of
      ``run_r[: out[b, r]]`` over all devices is exactly the first
      ``i[b]`` elements of the global merge.  Ties break by device order
      (lower device id first), matching ``co_rank_kway``.

    Every round costs one ``all_gather`` of ``(B, p)`` int32 candidates
    and two ``psum``s of ``(p, B, p)`` scalars; the round count is the
    engine's static ``kway_round_bound(w)``.  No run element ever
    leaves its device.
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    w = run_shard.shape[0]
    i = jnp.asarray(i, jnp.int32)
    b = i.shape[0]
    if length is None:
        lengths = jnp.full((p,), w, jnp.int32)
    else:
        lengths = lax.all_gather(
            jnp.asarray(length, jnp.int32), axis_name
        )  # (p,)

    probe = _CollectiveProbe(run_shard, axis_name, lengths, b)
    return engine.co_rank_search(
        i[:, None],
        probe,
        metric="splitters.kway_rounds",
        labels={"w": w, "batch": b, "device": r},
    )


# ---------------------------------------------------------------------------
# value-keyed segment cuts (one round: boundary values known a priori)
# ---------------------------------------------------------------------------


def distributed_segment_cuts(
    run_shard: jax.Array,
    n_segments: int,
    axis_name: str,
    length: jax.Array | None = None,
) -> jax.Array:
    """All ``n_segments + 1`` global segment boundaries over ``p`` runs.

    Call inside ``shard_map``.  Device ``r`` holds ``run_shard`` — its
    locally sorted run of integer segment keys in ``[0, n_segments)``
    (MoE: the stable-sorted expert ids of its local assignments; ragged
    runs pad with any value ``>= n_segments``, e.g. int32 max, and
    declare ``length``).

    Returns int32 ``(p, n_segments + 1)``, **replicated** on every
    device: entry ``[d, s]`` is the number of device ``d``'s elements
    with key ``< s``.  Consequences, all exact:

    * ``cuts[:, s].sum()`` is segment ``s``'s global start rank, and
      ``cuts[:, s + 1] - cuts[:, s]`` the per-(device, segment) counts —
      the complete send/receive schedule of a dropless exchange;
    * column ``s`` equals the ``distributed_co_rank_kway`` cut vector of
      the boundary *rank* ``cuts[:, s].sum()`` (all equal keys sort
      after the boundary, so value cuts and rank cuts coincide — the
      engine's ``value_cut_counts`` degenerate case);
    * the cut matrix is the whole metadata: ``O(p * E)`` int32 scalars
      in one ``all_gather`` round — the known boundary values collapse
      the co-rank search's ``O(log w)`` rounds to one.
    """
    bounds = jnp.arange(n_segments + 1, dtype=run_shard.dtype)
    local = engine.value_cut_counts(
        run_shard,
        bounds,
        None if length is None else jnp.asarray(length, jnp.int32),
    )
    cuts = lax.all_gather(local, axis_name)  # (p, n_segments + 1)
    if obs.enabled():
        p = cuts.shape[0]
        obs.counter(
            "splitters.segment_cut_scalars",
            p * (n_segments + 1),
            n_segments=n_segments,
            device=lax.axis_index(axis_name),
        )
    return cuts
