"""Distributed co-ranking: exact global splitters over collectives.

The paper's central object — the co-rank of an output rank ``i`` — is a
pure *search*, so it distributes without moving any run data: every
remote probe is a value lookup or a ``searchsorted`` count that the run's
owner can answer locally, and the ``p`` devices' searches advance in
lock-step rounds of ``O(p^2)``-scalar collectives.

Two searches live here:

* ``distributed_co_rank`` — the pairwise Algorithm 1 (two sorted arrays
  sharded over the mesh).  Each binary-search step performs its four
  remote reads by publishing the wanted global indices (``all_gather`` of
  ``p`` int32) and answering with a masked ``psum`` — the owner
  contributes the value, everyone else zero.  ``O(log min(m, n))``
  rounds.

* ``distributed_co_rank_kway`` — the multi-way generalisation: ``p``
  sorted runs, one per device, and a *batch* of ``B`` output ranks per
  device (``B = 2`` for a block's two bounds).  All ``p * B`` cut-vector
  searches resolve together in ``O(log(N/p))`` lock-step rounds.  Per
  round each device publishes its ``(B, p)`` candidate indices (one
  ``all_gather``), answers value lookups into its own run (one masked
  ``psum``), and contributes its Lemma-1 tie-aware ``searchsorted``
  counts for every candidate value (one more ``psum``) — ``O(p^2 B)``
  scalars per round, never a single element of run data gathered.

* ``distributed_segment_cuts`` — the *value-keyed* degenerate case that
  MoE expert dispatch needs: when the boundary **values** are known a
  priori (segment ids ``0..E-1``), Lemma 1's binary search collapses to
  one local ``searchsorted`` per boundary, so all ``E + 1`` global
  segment boundaries resolve in a **single** collective round of
  ``O(p * E)`` int32 scalars.  The result agrees column-for-column with
  ``distributed_co_rank_kway`` evaluated at the boundary *ranks*
  (verified in ``tests/_moe_dropless_check.py``): every element with key
  ``< e`` precedes every element with key ``>= e`` in the stable merge,
  so the rank-``b_e`` cut vector is exactly the per-run ``< e`` counts.

Both return the same cuts as their single-device counterparts
(``repro.core.corank.co_rank`` / ``repro.core.kway.co_rank_kway``),
verified element-for-element in ``tests/_exchange_check.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs
from repro.core.compat import axis_size as _axis_size
from repro.core.corank import prop1_bound

__all__ = [
    "distributed_co_rank",
    "distributed_co_rank_kway",
    "distributed_segment_cuts",
]


# ---------------------------------------------------------------------------
# pairwise (Algorithm 1 over collectives)
# ---------------------------------------------------------------------------


def _remote_read(shard: jax.Array, gidx: jax.Array, axis_name: str):
    """Every device reads global element ``gidx`` (its own request) from the
    sharded array: publish indices, owners answer via masked psum.

    Out-of-range ``gidx`` (sentinel reads A[-1], A[m]) return +/-inf codes
    handled by the caller; here we clamp and also return validity.
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    sz = shard.shape[0]  # local shard size (uniform)
    wanted = lax.all_gather(gidx, axis_name)  # (p,) every device's request
    owner = jnp.clip(wanted // sz, 0, p - 1)
    local = jnp.where(owner == r, wanted - r * sz, 0)
    vals = shard[jnp.clip(local, 0, sz - 1)]  # (p,) my answers
    answers = lax.psum(
        jnp.where(owner == r, vals, jnp.zeros_like(vals)), axis_name
    )
    return answers[r]


def distributed_co_rank(
    i: jax.Array, a_shard: jax.Array, b_shard: jax.Array, axis_name: str
):
    """Algorithm 1 with remote reads over collectives (per-device rank i).

    Each device searches for the co-ranks of its own ``i``; the p searches
    run in lock-step rounds (a fixed ``ceil(log2 min(m,n)) + 2`` count so
    the loop is static).  Returns ``(j, k)`` global co-ranks.
    """
    p = _axis_size(axis_name)
    m = a_shard.shape[0] * p
    n = b_shard.shape[0] * p
    i = jnp.asarray(i, jnp.int32)

    j = jnp.minimum(i, m)
    k = i - j
    j_low = jnp.maximum(jnp.int32(0), i - n)
    # k_low is derived from i so its shard_map varying-axes type matches
    # the loop body's output (i is per-device inside shard_map).
    k_low = i * 0

    rounds = max(1, min(m, n).bit_length() + 2)

    def body(_, state):
        j, k, j_low, k_low = state
        a_jm1 = _remote_read(a_shard, jnp.maximum(j - 1, 0), axis_name)
        b_k = _remote_read(b_shard, jnp.minimum(k, n - 1), axis_name)
        b_km1 = _remote_read(b_shard, jnp.maximum(k - 1, 0), axis_name)
        a_j = _remote_read(a_shard, jnp.minimum(j, m - 1), axis_name)

        fv = (j > 0) & (k < n) & (a_jm1 > b_k)
        sv = (k > 0) & (j < m) & (b_km1 >= a_j)

        delta_j = (j - j_low + 1) // 2
        delta_k = (k - k_low + 1) // 2
        new_k_low = jnp.where(fv, k, k_low)
        new_j_low = jnp.where(fv | ~sv, j_low, j)
        new_j = jnp.where(fv, j - delta_j, jnp.where(sv, j + delta_k, j))
        new_k = jnp.where(fv, k + delta_j, jnp.where(sv, k - delta_k, k))
        return new_j, new_k, new_j_low, new_k_low

    j, k, _, _ = lax.fori_loop(0, rounds, body, (j, k, j_low, k_low))
    if obs.enabled():
        # The lock-step distributed search runs a fixed padded schedule of
        # ``ceil(log2(min(m,n)+1)) + 2`` rounds (one convergence round +
        # one safety round over the per-device dynamic searches); the
        # truly dynamic Prop-1 counter is ``corank.iterations``.
        obs.gauge(
            "splitters.pairwise_rounds",
            rounds,
            bound=rounds,
            prop1_bound=prop1_bound(m, n),
            m=m,
            n=n,
            device=lax.axis_index(axis_name),
        )
    return j, k


# ---------------------------------------------------------------------------
# k-way (one sorted run per device, batched ranks)
# ---------------------------------------------------------------------------


def distributed_co_rank_kway(
    i: jax.Array,
    run_shard: jax.Array,
    axis_name: str,
    length: jax.Array | None = None,
) -> jax.Array:
    """Cut matrices of output ranks ``i`` into the mesh's ``p`` sorted runs.

    Call inside ``shard_map``.  Device ``r`` holds ``run_shard`` — sorted
    run ``r`` of the global k-way merge (``k = p``), width ``w`` — and
    asks for the cut vectors of *its own* ``B`` output ranks ``i``.

    Args:
      i: ``(B,)`` output ranks of this device (``B`` static, uniform).
      run_shard: ``(w,)`` this device's sorted run.  Ragged runs must be
        padded with row-maximal values and declare ``length``.
      axis_name: mesh axis the runs are sharded over.
      length: optional scalar count of real elements in ``run_shard``.

    Returns:
      int32 ``(B, p)``: row ``b`` is the cut vector of rank ``i[b]`` —
      ``out[b].sum() == min(i[b], total)`` and the stable k-way merge of
      ``run_r[: out[b, r]]`` over all devices is exactly the first
      ``i[b]`` elements of the global merge.  Ties break by device order
      (lower device id first), matching ``co_rank_kway``.

    Every round costs one ``all_gather`` of ``(B, p)`` int32 candidates
    and two ``psum``s of ``(p, B, p)`` scalars; the round count is the
    static ``ceil(log2 w) + 1``.  No run element ever leaves its device.
    """
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    w = run_shard.shape[0]
    i = jnp.asarray(i, jnp.int32)
    b = i.shape[0]
    run_ids = jnp.arange(p, dtype=jnp.int32)
    if length is None:
        lengths = jnp.full((p,), w, jnp.int32)
    else:
        lengths = lax.all_gather(
            jnp.asarray(length, jnp.int32), axis_name
        )  # (p,)

    def merged_rank(t: jax.Array) -> jax.Array:
        """rank(r', t[., r']) for this device's candidates ``t`` (B, p)."""
        # Publish every device's candidate indices: (p, B, p); entry
        # [d, q, rp] is device d's probe into run rp for its rank i[q].
        cand = lax.all_gather(t, axis_name)
        # Owners answer the value lookups: my column rp == r.
        mine = run_shard[jnp.clip(cand[:, :, r], 0, w - 1)]  # (p, B)
        vals = lax.psum(
            jnp.where(
                run_ids[None, None, :] == r,
                mine[:, :, None],
                jnp.zeros((), run_shard.dtype),
            ),
            axis_name,
        )  # (p, B, p): vals[d, q, rp] = run_rp[cand[d, q, rp]]
        # My Lemma-1 count contribution for every candidate value: runs
        # before the candidate's own run count ties (<=, side='right'),
        # runs after it count strictly (<, side='left').
        flat = vals.reshape(-1)
        ssl = jnp.searchsorted(run_shard, flat, side="left")
        ssr = jnp.searchsorted(run_shard, flat, side="right")
        cnt = jnp.where(
            r < run_ids[None, None, :],
            ssr.astype(jnp.int32).reshape(p, b, p),
            ssl.astype(jnp.int32).reshape(p, b, p),
        )
        cnt = jnp.where(r == run_ids[None, None, :], 0, cnt)
        cnt = jnp.minimum(cnt, lengths[r])  # never count my padding
        ranks = lax.psum(cnt, axis_name) + cand  # (p, B, p)
        return ranks[r]  # (B, p) — my own searches

    rounds = max(1, w).bit_length() + 1

    def body(_, lo_hi):
        lo, hi = lo_hi
        mid = (lo + hi) // 2
        pred = (mid < lengths[None, :]) & (merged_rank(mid) < i[:, None])
        return jnp.where(pred, mid + 1, lo), jnp.where(pred, hi, mid)

    # + i*0 keeps shard_map's varying-axes type aligned with the body.
    lo = jnp.zeros((b, p), jnp.int32) + i[:, None] * 0
    hi = jnp.broadcast_to(lengths[None, :], (b, p)) + i[:, None] * 0
    lo, _ = lax.fori_loop(0, rounds, body, (lo, hi))
    if obs.enabled():
        # ``rounds == ceil(log2(w + 1)) + 1`` — Prop 1's bound over the
        # ``w + 1`` candidate cuts, plus the one convergence round the
        # static lock-step schedule pays.
        obs.gauge(
            "splitters.kway_rounds",
            rounds,
            bound=max(1, w).bit_length() + 1,
            w=w,
            batch=b,
            device=r,
        )
    return lo


# ---------------------------------------------------------------------------
# value-keyed segment cuts (one round: boundary values known a priori)
# ---------------------------------------------------------------------------


def distributed_segment_cuts(
    run_shard: jax.Array,
    n_segments: int,
    axis_name: str,
    length: jax.Array | None = None,
) -> jax.Array:
    """All ``n_segments + 1`` global segment boundaries over ``p`` runs.

    Call inside ``shard_map``.  Device ``r`` holds ``run_shard`` — its
    locally sorted run of integer segment keys in ``[0, n_segments)``
    (MoE: the stable-sorted expert ids of its local assignments; ragged
    runs pad with any value ``>= n_segments``, e.g. int32 max, and
    declare ``length``).

    Returns int32 ``(p, n_segments + 1)``, **replicated** on every
    device: entry ``[d, s]`` is the number of device ``d``'s elements
    with key ``< s``.  Consequences, all exact:

    * ``cuts[:, s].sum()`` is segment ``s``'s global start rank, and
      ``cuts[:, s + 1] - cuts[:, s]`` the per-(device, segment) counts —
      the complete send/receive schedule of a dropless exchange;
    * column ``s`` equals the ``distributed_co_rank_kway`` cut vector of
      the boundary *rank* ``cuts[:, s].sum()`` (all equal keys sort
      after the boundary, so value cuts and rank cuts coincide);
    * the cut matrix is the whole metadata: ``O(p * E)`` int32 scalars
      in one ``all_gather`` round — the known boundary values collapse
      the co-rank search's ``O(log w)`` rounds to one.
    """
    bounds = jnp.arange(n_segments + 1, dtype=run_shard.dtype)
    local = jnp.searchsorted(run_shard, bounds, side="left").astype(jnp.int32)
    if length is not None:
        local = jnp.minimum(local, jnp.asarray(length, jnp.int32))
    cuts = lax.all_gather(local, axis_name)  # (p, n_segments + 1)
    if obs.enabled():
        p = cuts.shape[0]
        obs.counter(
            "splitters.segment_cut_scalars",
            p * (n_segments + 1),
            n_segments=n_segments,
            device=lax.axis_index(axis_name),
        )
    return cuts
