"""Sharded merge/sort entry points: one ``strategy=`` switch, three ways
to move (or not move) the data.

All strategies use the *same* exact co-rank partition — every device
produces exactly its ``N/p``-element output block — and differ only in
memory and wire traffic:

* ``"allgather"`` — CREW-PRAM emulation: replicate the runs with one
  ``all_gather`` (``O(N)`` memory and receive traffic per device), then
  every device co-ranks and merges its block locally.  Right when the
  merged data is consumed device-locally and ``N/p`` is small (routing
  metadata, sampler state); caps scaling at what one device can hold.

* ``"corank"`` (pairwise merge only) — the search is distributed
  (``O(log)`` rounds of ``O(p)``-scalar collectives, nothing gathered
  during the search), then the data for the local windows is still
  fetched with one ``all_gather``.  The faithful Siebert-Träff split of
  search vs. data movement; same ``O(N)`` data traffic as allgather.

* ``"exchange"`` — the no-replication path: distributed k-way co-rank
  splitters (``O(log(N/p))`` rounds, ``O(p^2)`` scalars each), then a
  balanced ``all_to_all`` ships each device exactly its block's
  segments (``O(N/p)`` real payload per device), then one local ragged
  k-way merge.  Per-device working set is the ``(p, capacity)`` slot
  buffer — ``O(N/p)`` per peer, no full-``N`` ``all_gather`` of values
  anywhere in the traced program.

Everything here is SPMD code to be called inside ``shard_map``; the
``*_host`` wrapper builds the mesh, pads uneven sizes with sentinels and
strips them again.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs
from repro.core.compat import axis_size as _axis_size
from repro.core.compat import shard_map as _shard_map
from repro.core.corank import co_rank
from repro.core.kway import co_rank_kway_batch, merge_kway_ranked
from repro.core.merge import merge_by_ranking
from repro.core.mergesort import DEFAULT_FANOUT, merge_sort
from repro.distributed.exchange import exchange_block, sentinel_max, window
from repro.distributed.splitters import (
    distributed_co_rank,
    distributed_co_rank_kway,
)

__all__ = [
    "distributed_merge",
    "distributed_merge_corank",
    "distributed_sort",
    "sharded_merge_kway",
    "sharded_sort",
    "sharded_sort_host",
]

MergeStrategy = Literal["allgather", "corank"]
SortStrategy = Literal["allgather", "exchange"]


# ---------------------------------------------------------------------------
# pairwise merge (allgather | corank)
# ---------------------------------------------------------------------------


def distributed_merge(
    a_shard: jax.Array,
    b_shard: jax.Array,
    axis_name: str,
    strategy: MergeStrategy = "allgather",
) -> jax.Array:
    """Stable merge of two sorted, evenly sharded arrays.

    Call inside ``shard_map``.  ``a_shard``/``b_shard`` are this device's
    contiguous shards; the global arrays are their concatenations in
    device order.  Returns this device's contiguous shard of the merged
    output (size ``(m+n)/p``; ``m+n`` must be divisible by ``p`` —
    framework callers pad with sentinels upstream).

    ``strategy="allgather"`` co-ranks on replicated arrays (CREW
    emulation); ``strategy="corank"`` runs the co-rank search itself over
    collectives (``distributed_co_rank``) and gathers only for the data
    windows.  The old ``strategy`` parameter accepted only the literal
    ``"allgather"``; that single-literal form is deprecated in favour of
    this switch.
    """
    if strategy == "corank":
        return distributed_merge_corank(a_shard, b_shard, axis_name)
    if strategy != "allgather":
        raise ValueError(
            f"distributed_merge strategy must be 'allgather' or 'corank', "
            f"got {strategy!r}"
        )
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    a = lax.all_gather(a_shard, axis_name, tiled=True)
    b = lax.all_gather(b_shard, axis_name, tiled=True)
    m, n = a.shape[0], b.shape[0]
    total = m + n
    assert total % p == 0, "pad inputs so p divides m+n"
    s = total // p

    i_lo = r * s
    j_lo, k_lo, _ = co_rank(i_lo, a, b)
    j_hi, k_hi, _ = co_rank(i_lo + s, a, b)

    # Static-size windows of length s cover the exact segments
    # (la + lb == s).  Out-of-segment lanes are masked to +sentinel so the
    # first s merged outputs are exactly this block.
    aw = window(a, j_lo, j_hi, s)
    bw = window(b, k_lo, k_hi, s)
    return merge_by_ranking(aw, bw)[:s]


def distributed_merge_corank(
    a_shard: jax.Array, b_shard: jax.Array, axis_name: str
) -> jax.Array:
    """Merge with distributed co-rank for the partition (data still fetched
    with one all_gather for the local windows; the *search* is distributed —
    this is the faithful [13]-style split of search vs. data movement)."""
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    m = a_shard.shape[0] * p
    n = b_shard.shape[0] * p
    total = m + n
    s = total // p
    j_lo, k_lo = distributed_co_rank(r * s, a_shard, b_shard, axis_name)
    j_hi, k_hi = distributed_co_rank(
        jnp.minimum((r + 1) * s, total), a_shard, b_shard, axis_name
    )
    a = lax.all_gather(a_shard, axis_name, tiled=True)
    b = lax.all_gather(b_shard, axis_name, tiled=True)
    aw = window(a, j_lo, j_hi, s)
    bw = window(b, k_lo, k_hi, s)
    return merge_by_ranking(aw, bw)[:s]


# ---------------------------------------------------------------------------
# k-way merge / sort (allgather | exchange)
# ---------------------------------------------------------------------------


def sharded_merge_kway(
    run_shard: jax.Array,
    axis_name: str,
    strategy: SortStrategy = "exchange",
    capacity: int | None = None,
) -> jax.Array:
    """Global stable k-way merge of ``p`` sorted runs, one per device.

    Call inside ``shard_map``.  Device ``r`` holds sorted run ``r``
    (width ``N/p``); returns its contiguous ``N/p``-element block of the
    global merge (ties break by device order — bit-exact with a global
    stable sort of the concatenation when the runs are locally sorted
    shards).

    ``strategy="exchange"`` (default): distributed splitters + balanced
    ``all_to_all`` + local ragged merge — no run is ever replicated.
    ``strategy="allgather"``: replicate the runs, cut locally — the old
    ``distributed_sort`` data path.

    ``capacity`` tunes the exchange's per-peer slot.  The default
    (``None`` = ``N/p``) is exact for every input.  A smaller capacity
    trades exactness for memory: any (sender, receiver) segment longer
    than ``capacity`` is truncated — the dropped elements vanish and the
    block's tail is zero-filled — acceptable for MoE-style capacity
    dropping, **incorrect for a sort**.  Only shrink it when segment
    skew is provably bounded (e.g. keys randomly shuffled across shards,
    where segments concentrate near ``N/p^2``); the truncation semantics
    are pinned down in ``tests/_exchange_check.py``.
    """
    if strategy not in ("allgather", "exchange"):
        raise ValueError(
            f"sharded sort/merge strategy must be 'allgather' or "
            f"'exchange', got {strategy!r}"
        )
    p = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    w = run_shard.shape[0]
    s = w  # every output block is exactly N/p elements (Proposition 2)
    bounds = jnp.stack([r * s, (r + 1) * s]).astype(jnp.int32)

    with obs.span(f"repro.sharded_merge_kway.{strategy}"):
        if strategy == "exchange":
            with obs.span("repro.splitters"):
                cuts = distributed_co_rank_kway(bounds, run_shard, axis_name)
            segments, lengths = exchange_block(
                run_shard, cuts, axis_name, capacity=capacity
            )
            with obs.span("repro.local_merge"):
                return merge_kway_ranked(segments, lengths=lengths, out_len=s)
        runs = lax.all_gather(run_shard, axis_name)  # (p, N/p) replicated
        cuts = co_rank_kway_batch(bounds, runs)  # (2, p) local cuts
        lo, hi = cuts[0], cuts[1]
        windows = jax.vmap(lambda row, a, b: window(row, a, b, s))(
            runs, lo, hi
        )
        return merge_kway_ranked(windows, lengths=hi - lo, out_len=s)


def sharded_sort(
    x_shard: jax.Array,
    axis_name: str,
    strategy: SortStrategy = "exchange",
    capacity: int | None = None,
    fanout: int = DEFAULT_FANOUT,
) -> jax.Array:
    """Globally stable sort of an evenly sharded array.

    Local stable merge sort (fan-out ``fanout``), then the strategy's
    splitter + data-movement path (``sharded_merge_kway``).  Stability
    across shards: device order breaks ties (shard ``d``'s elements
    precede shard ``d+1``'s equal elements), matching a global stable
    sort of the concatenated input.
    """
    with obs.span("repro.sharded_sort"):
        with obs.span("repro.local_sort"):
            local = merge_sort(x_shard, fanout=fanout)
        return sharded_merge_kway(
            local, axis_name, strategy=strategy, capacity=capacity
        )


def distributed_sort(
    x_shard: jax.Array,
    axis_name: str,
    strategy: SortStrategy = "exchange",
) -> jax.Array:
    """Back-compat alias of ``sharded_sort`` (exchange path by default)."""
    return sharded_sort(x_shard, axis_name, strategy=strategy)


# ---------------------------------------------------------------------------
# host-level wrapper (mesh construction + sentinel padding)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _sharded_sort_fn(mesh, axis_name, strategy, capacity):
    from jax.sharding import PartitionSpec as P

    return jax.jit(
        _shard_map(
            lambda s: sharded_sort(
                s, axis_name, strategy=strategy, capacity=capacity
            ),
            mesh=mesh,
            in_specs=(P(axis_name),),
            out_specs=P(axis_name),
        )
    )


def sharded_sort_host(
    x: jax.Array,
    strategy: SortStrategy = "exchange",
    axis_name: str = "x",
    mesh=None,
    capacity: int | None = None,
) -> jax.Array:
    """Host-callable global stable sort over every visible device.

    Handles what the SPMD core cannot: builds the 1-D mesh, pads
    non-power-of-two / uneven-remainder sizes to a multiple of ``p`` with
    order-preserving sentinels (dtype max sorts to the global tail, after
    every real element — including real dtype-max duplicates, which
    precede the padding by position), sorts, and strips the pad.
    """
    import numpy as np
    from jax.sharding import Mesh

    n = x.shape[0]
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (axis_name,))
    p = int(mesh.shape[axis_name])
    if n == 0 or p == 1:
        return merge_sort(x)
    w = -(-n // p)
    pad = w * p - n
    xp = (
        jnp.concatenate([x, jnp.full((pad,), sentinel_max(x.dtype))])
        if pad
        else x
    )
    out = _sharded_sort_fn(mesh, axis_name, strategy, capacity)(xp)
    return out[:n]
