"""Balanced ``all_to_all`` exchange built on exact splitters.

Because the splitters are *exact* co-ranks (the paper's perfect balance),
every device's output block is exactly ``N/p`` elements — the exchange is
balanced by construction, unlike sample sort's 2x capacity slack.  What
is *not* balanced is the per-(sender, receiver) segment: on adversarial
data (e.g. an already-sorted array) one peer pair can carry a whole
``N/p`` block while the others carry nothing.  SPMD programs need static
shapes, so the exchange ships fixed-capacity slots:

* each sender packs, for every peer, a ``(capacity,)`` slot holding the
  co-rank segment of its run destined for that peer (head = real
  elements, tail = order-preserving sentinel padding);
* one ``lax.all_to_all`` transposes the ``(p, capacity)`` slot matrix so
  receiver ``d`` ends with slot row ``r`` = the segment sent by run
  ``r`` — rows arrive in device order, which is exactly the k-way merge's
  tie-break order, so stability and duplicates survive the wire;
* a ``lengths`` sideband (the receiver's own cut differences — no extra
  collective) tells the ragged k-way merge where real data ends, so
  sentinel values that also occur in the payload are never confused with
  padding.

``capacity`` defaults to the worst-case-safe ``N/p``; callers with
shuffled data can shrink it (segments truncate like MoE capacity slots —
same static-slot idiom, same trade-off, see ``slot_transpose``).  Real
payload received per device is exactly ``N/p`` regardless of capacity —
the allgather strategy receives ``(p-1) * N/p`` — and a ragged
``all_to_allv`` (or TPU DMA-with-lengths) would put the wire bytes at
``N/p`` too; the slot padding is the price of static shapes only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs
from repro.core.mergesort import sentinel_max

__all__ = [
    "balanced_exchange",
    "exchange_block",
    "slot_transpose",
    "sentinel_max",
    "window",
    "window_rows",
]


def window(x: jax.Array, lo, hi, s: int) -> jax.Array:
    """``x[lo:hi]`` placed at the head of a length-``s`` buffer, tail =
    sentinel.  ``lo``/``hi`` may be traced; ``hi - lo`` must be ``<= s``
    for the copy to be lossless."""
    n = x.shape[0]
    xp = jnp.concatenate([x, jnp.full((s,), sentinel_max(x.dtype))])
    w = lax.dynamic_slice(xp, (jnp.minimum(lo, n),), (s,))
    mask = jnp.arange(s, dtype=jnp.int32) < (hi - lo)
    return jnp.where(mask, w, sentinel_max(x.dtype))


def window_rows(x: jax.Array, lo, hi, s: int) -> jax.Array:
    """Rows ``x[lo:hi]`` head-packed into a ``(s, d)`` buffer, tail
    zero-filled.  The payload analogue of ``window`` (keys get the
    order-preserving sentinel; payload rows past the segment are dead and
    zeros keep them inert under scatter-add combines)."""
    n, d = x.shape
    xp = jnp.concatenate([x, jnp.zeros((s, d), x.dtype)])
    w = lax.dynamic_slice(xp, (jnp.minimum(lo, n), 0), (s, d))
    mask = jnp.arange(s, dtype=jnp.int32) < (hi - lo)
    return jnp.where(mask[:, None], w, jnp.zeros((), x.dtype))


def balanced_exchange(
    send: jax.Array,
    lengths: jax.Array | None = None,
    *,
    axis_name: str | None = None,
    constrain=None,
    in_spec=None,
    out_spec=None,
):
    """Ragged balanced ``all_to_all``: slots + an exact lengths sideband.

    The one exchange primitive every dispatch path shares.  ``send`` is a
    ``(p, capacity, ...)`` slot buffer — row ``d`` head-packed with
    ``lengths[d]`` real elements destined for peer ``d`` (tail =
    padding).  Returns ``(recv, recv_lengths)``: ``recv`` row ``src`` is
    the segment peer ``src`` sent to this device (head-packed, same
    capacity), ``recv_lengths`` the transposed sideband — receiver
    ``r``'s entry ``src`` is exactly sender ``src``'s ``lengths[r]``, so
    raggedness is *accounted*, never inferred: real payload ends where
    the sideband says, and sentinel values occurring in the payload are
    never confused with padding.  The wire cost of the sideband is ``p``
    int32 — ``O(p^2)`` scalars mesh-wide, the same metadata class as the
    splitters.

    ``lengths=None`` is the static-shape special case — every slot is
    taken to be full, no sideband travels, and ``recv_lengths`` is
    ``None``.  That case is exactly ``slot_transpose``: the MoE
    capacity-slot dispatch is this exchange with the raggedness
    forfeited (truncate/pad to ``capacity``), the dropless dispatch is
    the same exchange keeping it.

    Two forms, selected by ``axis_name``:

    * ``axis_name`` given — explicit-collective form for ``shard_map``
      code: one ``lax.all_to_all`` for the slots (+ one for the
      sideband).
    * ``axis_name=None`` — jit-level GSPMD form: the exchange is written
      as a swap of the two leading (peer-group, slot) axes under
      ``constrain``/``in_spec``/``out_spec`` sharding constraints, which
      lowers to one all_to_all of equal bytes per peer (no sideband —
      jit-level callers are the static-shape case).
    """
    if axis_name is None:
        if lengths is not None:
            raise ValueError(
                "balanced_exchange: the ragged form (lengths sideband) "
                "needs explicit collectives — call it inside shard_map "
                "with axis_name"
            )
        if constrain is not None and in_spec is not None:
            send = constrain(send, *in_spec)
        recv = jnp.swapaxes(send, 0, 1)
        if constrain is not None and out_spec is not None:
            recv = constrain(recv, *out_spec)
        return recv, None
    recv = lax.all_to_all(
        send, axis_name, split_axis=0, concat_axis=0, tiled=True
    )
    recv_lengths = None
    if lengths is not None:
        recv_lengths = lax.all_to_all(
            jnp.asarray(lengths, jnp.int32),
            axis_name,
            split_axis=0,
            concat_axis=0,
            tiled=True,
        )
    return recv, recv_lengths


def exchange_block(
    run_shard: jax.Array,
    cuts: jax.Array,
    axis_name: str,
    capacity: int | None = None,
):
    """Ship every device its exact output block's segments.

    Call inside ``shard_map``.  ``cuts`` is this device's ``(2, p)`` cut
    matrix from ``distributed_co_rank_kway`` — row 0/1 the cut vectors of
    its block's lower/upper rank.  Device ``r`` must *send* according to
    everyone else's cuts restricted to run ``r``, so the cut matrices are
    shared first (one ``all_gather`` of ``2 p^2`` int32 — the only
    metadata collective the exchange adds).

    Returns ``(segments, lengths)``: ``segments`` is ``(p, capacity)``
    with row ``src`` = the co-rank segment of run ``src`` belonging to
    this device's block (head-packed, sentinel tail) and ``lengths`` the
    ``(p,)`` real segment lengths (``lengths.sum() == block size``, the
    perfect-balance guarantee).  Feed both to ``merge_kway_ranked`` for
    the local stable merge.

    ``capacity`` bounds the per-peer slot; ``None`` means the safe
    ``run_shard.shape[0]`` (= ``N/p``).  A smaller capacity truncates
    oversized segments — the receiver's ragged merge then drops the
    missing elements and zero-fills its block tail (MoE-style capacity
    dropping; wrong for an exact sort — see ``sharded_merge_kway``).
    Segments exceed ``N/p^2`` only on skewed data; adversarially
    pre-sorted input drives one segment to the full ``N/p``.
    """
    w = run_shard.shape[0]
    r = lax.axis_index(axis_name)
    cap = w if capacity is None else int(capacity)
    with obs.span("repro.exchange_block"):
        cuts = jnp.asarray(cuts, jnp.int32)
        all_cuts = lax.all_gather(cuts, axis_name)  # (p, 2, p)
        lo_mine = all_cuts[:, 0, r]  # (p,) peers' segment bounds in MY run
        hi_mine = all_cuts[:, 1, r]
        send = jax.vmap(lambda a, b: window(run_shard, a, b, cap))(
            lo_mine, hi_mine
        )  # (p, cap): row d = my segment for peer d
        # Wire sideband: sender r's entry d is cuts_d[1, r] - cuts_d[0, r],
        # so after the exchange receiver d's entry r equals its own
        # cuts[1, r] - cuts[0, r] — the sideband and the receiver-local cut
        # differences provably agree (asserted in tests/_exchange_check.py).
        send_lengths = jnp.minimum(hi_mine - lo_mine, cap)
        segments, lengths = balanced_exchange(
            send, send_lengths, axis_name=axis_name
        )  # (p, cap): row src = run src's segment for me
        if obs.enabled():
            p = segments.shape[0]
            itemsize = jnp.dtype(run_shard.dtype).itemsize
            obs.gauge(
                "exchange.send_lengths", send_lengths, capacity=cap, device=r
            )
            obs.gauge(
                "exchange.peer_bytes",
                lengths * itemsize,
                capacity=cap,
                itemsize=itemsize,
                device=r,
            )
            # Proposition 2 over the wire: real elements received == the
            # receiver's exact output block (N/p on the sort path).
            obs.gauge("exchange.block_elements", lengths.sum(), device=r)
            # Static-shape overhead: sentinel slots shipped vs real rows.
            obs.gauge(
                "exchange.padding_slots",
                p * cap - lengths.sum(),
                capacity=cap,
                device=r,
            )
            obs.gauge(
                "exchange.length_skew",
                lengths.max() - lengths.min(),
                device=r,
            )
    return segments, lengths


def slot_transpose(x: jax.Array, constrain=None, in_spec=None, out_spec=None):
    """Swap the two leading (peer-group, slot) axes of a capacity-padded
    dispatch buffer — the jit-level form of the balanced exchange.

    ``exchange_block`` is the explicit-collective form for ``shard_map``
    code; MoE expert-parallel dispatch lives at jit level where GSPMD
    inserts collectives, so there the balanced ``all_to_all`` is written
    as a transpose of ``(groups, experts, capacity, d)`` slots under
    sharding constraints: with ``groups`` on the batch axes and
    ``experts`` on the EP axis, the swap below lowers to exactly one
    all_to_all shipping equal bytes per peer — the same
    static-capacity-slot idiom, equal-split because capacity is static.

    ``constrain`` is a ``(x, *spec) -> x`` sharding-constraint callable
    (``repro.models.layers.constrain_spec``); ``in_spec``/``out_spec``
    are the partition-spec entries before/after the swap.  Pass ``None``
    to skip constraining (single-device paths).
    """
    recv, _ = balanced_exchange(
        x, constrain=constrain, in_spec=in_spec, out_spec=out_spec
    )
    return recv
