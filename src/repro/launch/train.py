"""Training launcher with fault-tolerant restart-from-latest loop.

``python -m repro.launch.train --arch granite-3-2b --steps 200 --smoke``

On real hardware the process-level launcher re-execs this on node failure;
here the same logic is exercised in-process: every run starts by probing
``latest_step`` and restoring params/optimizer/data position, so a SIGKILL
at any point loses at most ``--ckpt-every`` steps (checkpoints are atomic,
torn writes are ignored).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs.registry import ARCHS, smoke_config
from repro.data.pipeline import DataConfig, batches
from repro.models.transformer import init_params
from repro.train.optimizer import adamw_init
from repro.train.train_step import build_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--moe-dispatch", choices=("capacity", "dropless"),
                    default=None,
                    help="override ModelConfig.moe_dispatch (MoE archs)")
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_config(cfg)
    cfg = dataclasses.replace(cfg, learning_rate=args.lr)
    if args.moe_dispatch is not None:
        cfg = dataclasses.replace(cfg, moe_dispatch=args.moe_dispatch)

    params, _specs = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params, dtype=jnp.dtype(cfg.adam_dtype))
    start = 0

    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            like = jax.eval_shape(lambda: {"params": params, "opt": opt})
            state = restore_checkpoint(args.ckpt_dir, last, like)
            params, opt = state["params"], state["opt"]
            start = last
            print(f"[restore] resumed from step {last}")

    step_fn = jax.jit(build_train_step(cfg, total_steps=args.steps, warmup=10),
                      donate_argnums=(0, 1))
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    stream = batches(dc, start_step=start)

    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = next(stream)
        model_batch = {k: batch[k] for k in ("tokens", "labels", "mask")}
        if cfg.frontend != "none":
            model_batch["frontend_embeds"] = jnp.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
            )
        params, opt, metrics = step_fn(
            params, opt, model_batch, jnp.int32(step)
        )
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            tps = args.batch * args.seq * args.log_every / (time.time() - t0)
            print(
                f"step {step + 1:5d}  loss {losses[-1]:.4f}  "
                f"gnorm {float(metrics['gnorm']):.3f}  "
                f"lr {float(metrics['lr']):.2e}  tok/s {tps:,.0f}",
                flush=True,
            )
            t0 = time.time()
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(
                args.ckpt_dir, step + 1, {"params": params, "opt": opt}
            )
            print(f"[ckpt] step {step + 1}")

    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
