"""Training launcher with fault-tolerant restart-from-latest loop.

``python -m repro.launch.train --arch granite-3-2b --steps 200 --smoke``

On real hardware the process-level launcher re-execs this on node failure;
here the same logic is exercised in-process: every run starts by probing
``latest_step`` and restoring params/optimizer/data position, so a SIGKILL
at any point loses at most ``--ckpt-every`` steps (checkpoints are atomic,
torn writes are ignored).
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.checkpointer import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs.registry import ARCHS, smoke_config
from repro.data.pipeline import DataConfig, batches
from repro.models.transformer import init_params
from repro.train.optimizer import adamw_init
from repro.train.train_step import build_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--moe-dispatch", choices=("capacity", "dropless"),
                    default=None,
                    help="override ModelConfig.moe_dispatch (MoE archs)")
    ap.add_argument("--external-threshold", type=int, default=0,
                    help="bucket length-sort windows of >= N docs through "
                         "the out-of-core external sort (repro.external); "
                         "0 = always in-memory")
    ap.add_argument("--external-workdir", default="",
                    help="spill directory for --external-threshold "
                         "(default: per-process temp dir)")
    ap.add_argument("--metrics-dir", default="",
                    help="enable repro.obs metrics; JSONL lands here "
                         "(overrides ModelConfig.metrics_dir)")
    ap.add_argument("--profile-steps", type=int, default=0,
                    help="dump a jax.profiler trace covering the first N "
                         "steps (under <metrics-dir>/profile)")
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_config(cfg)
    cfg = dataclasses.replace(cfg, learning_rate=args.lr)
    if args.moe_dispatch is not None:
        cfg = dataclasses.replace(cfg, moe_dispatch=args.moe_dispatch)
    metrics_dir = args.metrics_dir or cfg.metrics_dir
    if metrics_dir:
        cfg = dataclasses.replace(cfg, metrics_dir=metrics_dir)
        obs.enable(metrics_dir=metrics_dir)

    params, _specs = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params, dtype=jnp.dtype(cfg.adam_dtype))
    start = 0

    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            like = jax.eval_shape(lambda: {"params": params, "opt": opt})
            state = restore_checkpoint(args.ckpt_dir, last, like)
            params, opt = state["params"], state["opt"]
            start = last
            print(f"[restore] resumed from step {last}")

    step_fn = jax.jit(build_train_step(cfg, total_steps=args.steps, warmup=10),
                      donate_argnums=(0, 1))
    dc = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, batch=args.batch,
        external_threshold=args.external_threshold,
        external_workdir=args.external_workdir,
    )
    stream = batches(dc, start_step=start)

    profiling = False
    if args.profile_steps > 0:
        obs.start_profile(os.path.join(metrics_dir or ".", "profile"))
        profiling = True

    t0 = time.time()
    losses = []
    hlo_reported = False
    for step in range(start, args.steps):
        batch = next(stream)
        model_batch = {k: batch[k] for k in ("tokens", "labels", "mask")}
        if cfg.frontend != "none":
            model_batch["frontend_embeds"] = jnp.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
            )
        if obs.enabled() and not hlo_reported:
            # Compile-time yardstick: the jitted entrypoint's predicted
            # collective traffic, reconciled against runtime byte counters.
            hlo_reported = True
            obs.attach_hlo_report(  # logs hlo.report_failed on error
                "train_step",
                step_fn.lower(params, opt, model_batch, jnp.int32(step)),
                arch=cfg.name,
            )
        obs.set_step(step)
        with obs.step_span("train", step):
            params, opt, metrics = step_fn(
                params, opt, model_batch, jnp.int32(step)
            )
            losses.append(float(metrics["loss"]))
        if obs.enabled():
            obs.gauge("train.loss", losses[-1])
            obs.flush()
        if profiling and step + 1 - start >= args.profile_steps:
            obs.stop_profile()
            profiling = False
        if (step + 1) % args.log_every == 0:
            tps = args.batch * args.seq * args.log_every / (time.time() - t0)
            print(
                f"step {step + 1:5d}  loss {losses[-1]:.4f}  "
                f"gnorm {float(metrics['gnorm']):.3f}  "
                f"lr {float(metrics['lr']):.2e}  tok/s {tps:,.0f}",
                flush=True,
            )
            t0 = time.time()
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(
                args.ckpt_dir, step + 1, {"params": params, "opt": opt}
            )
            print(f"[ckpt] step {step + 1}")

    if profiling:
        obs.stop_profile()
    if obs.enabled():
        obs.flush()
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
