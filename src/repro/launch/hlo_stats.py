"""Parse compiled HLO text for collective-traffic statistics.

``compiled.as_text()`` (post-SPMD-partitioning HLO) names every collective
op with its output shape.  Collectives inside ``while`` bodies (scan over
layers, grad-accum loop) execute once per trip, so we extract each loop's
trip count from its condition computation and multiply through the call
graph — otherwise a 95-layer model would under-count its collective bytes
95x.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# computation headers: '%name (params...) -> type {' — params may contain
# nested parens (tuple types), so match the name and the trailing '-> ... {'
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,512]' -> bytes; tuples: sum of components."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str) -> dict[str, str]:
    """Map computation name -> body text."""
    comps: dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m:
            if cur_name:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = m.group(1)
            cur_lines = [line]
        elif cur_name is not None:
            cur_lines.append(line)
            if line.strip() == "}":
                comps[cur_name] = "\n".join(cur_lines)
                cur_name, cur_lines = None, []
    if cur_name:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
)
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?\).*?calls=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")


def _trip_count(cond_body: str) -> int:
    """Heuristic: largest integer constant in the condition computation
    (scan conditions compare the induction variable against the length)."""
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    return max(consts) if consts else 1


def _multipliers(comps: dict[str, str]) -> dict[str, int]:
    """Execution-count multiplier per computation (while trip counts,
    composed through the call graph)."""
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for name, body in comps.items():
        for m in _WHILE_RE.finditer(body):
            cond, loop_body = m.group(1), m.group(2)
            trips = _trip_count(comps.get(cond, ""))
            edges[name].append((loop_body, trips))
            edges[name].append((cond, trips))
        for m in _CALL_RE.finditer(body):
            edges[name].append((m.group(1), 1))
    entry = None
    for name in comps:
        if "entry" in name.lower() or name.startswith("main"):
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))
    mult: dict[str, int] = defaultdict(int)
    mult[entry] = 1
    stack = [entry]
    seen = set()
    while stack:
        cur = stack.pop()
        for child, k in edges.get(cur, []):
            key = (cur, child)
            if key in seen:
                continue
            seen.add(key)
            mult[child] += mult[cur] * k
            stack.append(child)
    return dict(mult)


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_DOT_RE = re.compile(r"\bdot\(\s*%?([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _first_shape(txt: str):
    m = _SHAPE_RE.search(txt)
    if not m:
        return None, 0
    dt, dims = m.group(1), m.group(2)
    shape = [int(d) for d in dims.split(",")] if dims else []
    return shape, _DTYPE_BYTES.get(dt, 0)


def hlo_flops_bytes(hlo: str) -> dict:
    """Trip-count-weighted FLOPs (dots, x2 MAC) and HBM-traffic estimate.

    XLA's ``cost_analysis`` counts each while body ONCE; a 61-layer scanned
    model would be undercounted ~61x.  This walks the partitioned module
    with per-computation execution multipliers.  Byte traffic is estimated
    as 2x the produced bytes of every non-fused op (read ~= write on
    average); it is an estimate, which is all a static analysis can give.
    """
    comps = split_computations(hlo)
    mult = _multipliers(comps)

    # global symbol table: op name -> result shape text
    symbols: dict[str, str] = {}
    for body in comps.values():
        for line in body.splitlines():
            m = _DEF_RE.match(line)
            if m:
                symbols[m.group(1)] = m.group(2)

    # computations that are fusion internals (counted at the fusion site)
    fused_internal: set[str] = set()
    for body in comps.values():
        for line in body.splitlines():
            if re.search(r"\bfusion\(", line):
                mm = re.search(r"calls=%?([\w\.\-]+)", line)
                if mm:
                    fused_internal.add(mm.group(1))

    flops = 0
    bytes_rw = 0
    for name, body in comps.items():
        w = mult.get(name, 0)
        if w == 0:
            w = 1 if name not in fused_internal else 0
        if w == 0:
            continue
        internal = name in fused_internal
        for line in body.splitlines():
            m = _DEF_RE.match(line)
            if m is None:
                continue
            rhs = m.group(2)
            dm = _DOT_RE.search(rhs)
            if dm:
                out_shape, _ = _first_shape(rhs)
                lhs_txt = symbols.get(dm.group(1), "")
                lhs_shape, _ = _first_shape(lhs_txt)
                cm = _LHS_CONTRACT_RE.search(rhs)
                contract = 1
                if lhs_shape and cm and cm.group(1):
                    for d in cm.group(1).split(","):
                        di = int(d)
                        if di < len(lhs_shape):
                            contract *= lhs_shape[di]
                out_n = 1
                for d in out_shape or []:
                    out_n *= d
                flops += 2 * out_n * contract * w
            if not internal:
                bytes_rw += _line_traffic(rhs, symbols, w) * w
    return {"flops": int(flops), "bytes": int(bytes_rw)}


# ops that move no data (aliases, control flow, loop plumbing); collectives
# are excluded here because their traffic is charged to the collective term
_FREE_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "bitcast-convert", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute", "all-reduce-start",
    "all-reduce-done", "all-gather-start", "all-gather-done", "domain",
    "opt-barrier",
}
_OPNAME_RE = re.compile(r"(?:^|\s|\})([a-z][a-z0-9\-\.]*)\(")
_DUS_RE = re.compile(r"dynamic-update-slice\(\s*%?[\w\.\-]+,\s*%?([\w\.\-]+)")


def _line_traffic(rhs: str, symbols: dict[str, str], trips: int = 1) -> int:
    """HBM traffic estimate for one op: 2x produced bytes (read+write),
    EXCEPT aliasing/control ops (0) and dynamic-update-slice (2x the
    update operand — XLA updates the big buffer in place; counting the
    result would charge a scanned KV cache its full size per layer)."""
    m = _OPNAME_RE.search(rhs)
    op = m.group(1) if m else ""
    if op in _FREE_OPS:
        return 0
    if op == "dynamic-update-slice":
        dm = _DUS_RE.search(rhs)
        upd_txt = symbols.get(dm.group(1), "") if dm else ""
        shp, bpe = _first_shape(upd_txt)
        if shp is None or not bpe:
            return 0
        n = 1
        for d in shp:
            n *= d
        return 2 * n * bpe
    idx = m.start(1) if m else len(rhs)
    shp, bpe = _first_shape(rhs[:idx])
    if shp is None or not bpe:
        return 0
    n = 1
    for d in shp:
        n *= d
    nbytes = 2 * n * bpe
    # scan stacking fused with the update: XLA updates the stacked buffer
    # in place; charge one slice (leading dim = stack axis), not the whole
    # buffer per iteration.
    if op == "fusion" and (
        "dynamic_update_slice" in rhs or "dynamic-update-slice" in rhs
    ):
        nbytes //= max(shp[0], 1) if shp else 1
    elif op in ("fusion", "copy") and shp and shp[0] == trips > 1:
        # scan-carry stacking: leading dim == loop trip count means this is
        # the in-place stacked buffer; charge one slice per iteration.
        nbytes //= shp[0]
    elif op == "fusion" and shp and len(shp) > 1 and shp[0] > 1:
        # fused stack update: an operand aliases the full result buffer and
        # another operand is a leading-dim slice of it -> in-place DUS;
        # charge the slice, not the stack (the 80-layer remat carry case).
        ops_txt = rhs.split("(", 1)[1]
        names = re.findall(r"%([\w\.\-]+)", ops_txt[: ops_txt.find(")")])
        full_like = slice_bytes = 0
        for nm in names:
            oshp, obpe = _first_shape(symbols.get(nm, ""))
            if oshp is None:
                continue
            if oshp == shp:
                full_like += 1
            elif (
                len(oshp) == len(shp)
                and oshp[0] == 1
                and oshp[1:] == shp[1:]
            ):
                onb = obpe
                for d in oshp:
                    onb *= d
                slice_bytes = max(slice_bytes, onb)
        if full_like and slice_bytes:
            nbytes = 2 * slice_bytes
    return nbytes


def top_traffic(hlo: str, k: int = 15) -> list[tuple[float, str]]:
    """The dry-run 'profile': top-k HBM-traffic lines (trip-weighted GiB),
    with computation, op and shape — what to stare at before §Perf edits."""
    comps = split_computations(hlo)
    mult = _multipliers(comps)
    symbols: dict[str, str] = {}
    for body in comps.values():
        for line in body.splitlines():
            m = _DEF_RE.match(line)
            if m:
                symbols[m.group(1)] = m.group(2)
    fused_internal: set[str] = set()
    for body in comps.values():
        for line in body.splitlines():
            if re.search(r"\bfusion\(", line):
                mm = re.search(r"calls=%?([\w\.\-]+)", line)
                if mm:
                    fused_internal.add(mm.group(1))
    rows = []
    for name, body in comps.items():
        w = mult.get(name, 0) or (1 if name not in fused_internal else 0)
        if w == 0 or name in fused_internal:
            continue
        for line in body.splitlines():
            m = _DEF_RE.match(line)
            if not m:
                continue
            t = _line_traffic(m.group(2), symbols, w) * w
            if t:
                meta = re.search(r'op_name="([^"]*)"', m.group(2))
                tag = meta.group(1)[-70:] if meta else m.group(2)[:70]
                rows.append((t / 2**30, f"[{name} x{w}] {tag}"))
    rows.sort(reverse=True)
    return rows[:k]


def collective_op_sizes(hlo: str, op: str = "all-gather"):
    """``(dtype, element_count)`` of every ``op`` output in an HLO dump.

    Matches only real collective ops — the op name directly follows the
    result shape (``%x = s32[8,2,8]{...} all-gather(...)``); lines that
    merely *consume* a collective operand (fusions naming
    ``%all-gather.6``) must not count.  Used by the exchange subsystem's
    no-replication assertions (tests + benchmarks).

    Tuple-typed results (all-to-all on some backends) report one entry
    per op: the first component dtype and the summed element count.
    """
    pat = re.compile(
        r"=\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+"
        + re.escape(op)
        + r"(?:-start)?\("
    )
    out = []
    for m in pat.finditer(hlo):
        total, dtype = 0, None
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n
            dtype = dtype or dt
        if dtype is not None:
            out.append((dtype, total))
    return out


def collective_bytes(hlo: str) -> dict:
    """Total collective bytes (trip-count weighted) and per-op breakdown."""
    comps = split_computations(hlo)

    # call-graph edges with multipliers
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for name, body in comps.items():
        for m in _WHILE_RE.finditer(body):
            cond, loop_body = m.group(1), m.group(2)
            trips = _trip_count(comps.get(cond, ""))
            edges[name].append((loop_body, trips))
            edges[name].append((cond, trips))
        for m in _CALL_RE.finditer(body):
            edges[name].append((m.group(1), 1))

    # propagate multipliers from ENTRY
    entry = None
    for name in comps:
        if "entry" in name.lower() or name.startswith("main"):
            entry = name
            break
    if entry is None:
        entry = next(iter(comps))
    mult: dict[str, int] = defaultdict(int)
    mult[entry] = 1
    stack = [entry]
    seen_edges = set()
    while stack:
        cur = stack.pop()
        for child, k in edges.get(cur, []):
            key = (cur, child)
            if key in seen_edges:
                continue
            seen_edges.add(key)
            mult[child] += mult[cur] * k
            stack.append(child)

    per_op: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)
    for name, body in comps.items():
        w = mult.get(name, 1) or 1
        for line in body.splitlines():
            ls = line.strip()
            if "=" not in ls:
                continue
            rhs = ls.split("=", 1)[1]
            for op in COLLECTIVES:
                # count op-start or plain forms; skip -done (same traffic)
                m = re.search(rf"\b{op}(?:-start)?\(", rhs)
                if m and f"{op}-done" not in rhs:
                    shape_txt = rhs[: m.start()]  # result type incl. tuples
                    nbytes = _shape_bytes(shape_txt)
                    per_op[op] += nbytes * w
                    counts[op] += w
                    break
    return {
        "total_bytes": int(sum(per_op.values())),
        "per_op_bytes": {k: int(v) for k, v in per_op.items()},
        "op_counts": {k: int(v) for k, v in counts.items()},
    }
