import os
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={os.environ.get('DRYRUN_DEVICES', '512')} "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 or 2x16x16 host
devices), constructs ShapeDtypeStruct stand-ins for params / optimizer
state / batch / cache (nothing is ever allocated), jits the real step
function with the real sharding trees, and runs ``.lower().compile()``.
``memory_analysis()`` proves the cell fits; ``cost_analysis()`` plus the
collective bytes parsed from the partitioned HLO feed §Roofline.

Results are cached as JSON under results/dryrun/ (one file per cell);
``benchmarks/roofline.py`` turns them into the EXPERIMENTS.md tables.

Usage:
  python -m repro.launch.dryrun --all                     # every cell, both meshes
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --multi-pod
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import ARCHS, cell_runnable, input_specs
from repro.launch.hlo_stats import collective_bytes, hlo_flops_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import param_sharding, resolve_spec
from repro.models import layers as model_layers
from repro.models.transformer import (
    Cache,
    cache_specs,
    decode_step,
    init_params,
    prefill_logits,
)
from repro.train.optimizer import AdamWState, adamw_init
from repro.train.train_step import build_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")

# Per-cell gradient-accumulation overrides: keep per-microbatch activation
# memory (L x B_micro x S x D x 2B of remat carries per device) inside HBM.
GRAD_ACCUM = {
    ("deepseek-67b", "train_4k"): 16,
    ("qwen1.5-110b", "train_4k"): 16,
    ("deepseek-v3-671b", "train_4k"): 32,
    ("dbrx-132b", "train_4k"): 16,
    ("internvl2-26b", "train_4k"): 8,
    ("musicgen-medium", "train_4k"): 2,
    ("granite-3-2b", "train_4k"): 2,
    ("zamba2-1.2b", "train_4k"): 2,
    ("mamba2-2.7b", "train_4k"): 2,
}


def normalize_cost_analysis(cost) -> dict:
    """Normalise ``compiled.cost_analysis()`` across JAX versions.

    JAX <= 0.4.x returns a *list* with one properties-dict per computation,
    newer JAX returns the dict directly, and some backends return ``None``.
    Always returns a flat dict (first computation wins on key collisions).
    """
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    merged: dict = {}
    for entry in cost:
        if isinstance(entry, dict):
            for k, v in entry.items():
                merged.setdefault(k, v)
    return merged


def effective_batch_axes(mesh, batch: int, layout: str = "tp"):
    """Greedy prefix of the DP-capable axes whose product divides the
    batch.  layout='fsdp' adds 'model' to the pool: the model axis stops
    doing TP and joins data parallelism (ZeRO-3 weight gathering)."""
    pool = ("pod", "data", "model") if layout == "fsdp" else ("pod", "data")
    axes = []
    prod = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in pool:
        if a in sizes and batch % (prod * sizes[a]) == 0:
            axes.append(a)
            prod *= sizes[a]
    return tuple(axes)


def sanitize_specs(sds_tree, spec_tree, mesh):
    """Drop sharding on any axis that does not evenly divide the dim —
    e.g. vocab 49155 or 24 attention heads on a 16-wide model axis fall
    back to replication on that axis (standard GQA practice for
    n_kv < TP)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(sds, spec):
        if not isinstance(spec, P):
            return spec
        entries = list(spec) + [None] * (len(sds.shape) - len(spec))
        out = []
        for dim, entry in zip(sds.shape, entries):
            if entry is None:
                out.append(None)
                continue
            axs = entry if isinstance(entry, (tuple, list)) else (entry,)
            axs = [a for a in axs if a in sizes]
            prod = 1
            kept = []
            for a in axs:
                if dim % (prod * sizes[a]) == 0:
                    kept.append(a)
                    prod *= sizes[a]
            out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
        return P(*out)

    return jax.tree.map(
        fix, sds_tree, spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def _shardings(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, mesh)),
        tree_specs,
        is_leaf=lambda s: isinstance(s, P),
    )


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Returns (jitted_fn, example_args_sds) for one cell."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    merged = {"grad_accum": GRAD_ACCUM.get((arch, shape_name), 1)}
    merged.update(overrides or {})
    cfg = dataclasses.replace(cfg, **merged)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ba = effective_batch_axes(mesh, shape.global_batch, cfg.layout)
    model_layers.set_batch_axes(ba)  # residual-stream constraints

    # abstract params + specs (captured via trace side-channel)
    box = {}

    def only_params(key):
        p, s = init_params(cfg, key)
        box["specs"] = s
        return p

    params_sds = jax.eval_shape(only_params, jax.random.key(0))
    pspecs = sanitize_specs(params_sds, box["specs"], mesh)
    psh = _shardings(pspecs, mesh)

    batch_sds = input_specs(cfg, shape)

    if shape.kind == "train":
        opt_sds = jax.eval_shape(
            lambda p: adamw_init(p, dtype=jnp.dtype(cfg.adam_dtype)), params_sds
        )
        osh = AdamWState(
            step=NamedSharding(mesh, P()), m=psh.copy(), v=psh.copy()
        )
        bsh = {
            k: NamedSharding(mesh, P(ba, *(None,) * (len(v.shape) - 1)))
            for k, v in batch_sds.items()
        }
        step_fn = build_train_step(cfg)
        fn = jax.jit(
            step_fn,
            in_shardings=(psh, osh, bsh, NamedSharding(mesh, P())),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1),
        )
        args = (params_sds, opt_sds, batch_sds,
                jax.ShapeDtypeStruct((), jnp.int32))
        return mesh, cfg, fn, args

    if shape.kind == "prefill":
        bsh = {
            k: NamedSharding(mesh, P(ba, *(None,) * (len(v.shape) - 1)))
            for k, v in batch_sds.items()
        }

        def pf(params, batch):
            return prefill_logits(
                cfg, params, batch["tokens"], batch.get("frontend_embeds")
            )

        fn = jax.jit(pf, in_shardings=(psh, bsh), out_shardings=None)
        return mesh, cfg, fn, (params_sds, batch_sds)

    # decode
    cache_sds = batch_sds["cache"]
    cspec_tree = cache_specs(cfg, ba)
    cspecs = sanitize_specs(
        Cache(cache_sds.kind, cache_sds.data, jax.ShapeDtypeStruct((), jnp.int32)),
        Cache(cspec_tree.kind, cspec_tree.data, P()),
        mesh,
    )
    csh = jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, mesh)),
        cspecs,
        is_leaf=lambda s: isinstance(s, P),
    )
    tsh = NamedSharding(mesh, P(ba, None))

    def dc(params, cache, tokens):
        return decode_step(cfg, params, cache, tokens)

    fn = jax.jit(
        dc,
        in_shardings=(psh, csh, tsh),
        out_shardings=(None, csh),
        donate_argnums=(1,),
    )
    return mesh, cfg, fn, (params_sds, cache_sds, batch_sds["tokens"])


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False, overrides: dict | None = None,
             tag: str = "") -> dict:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_tag}" + (f"__{tag}" if tag else "")
    out_path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg0 = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, why = cell_runnable(cfg0, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "params": cfg0.param_count(),
        "active_params": cfg0.active_param_count(),
    }
    if not ok:
        rec.update(status="skipped", reason=why)
    else:
        try:
            t0 = time.time()
            mesh, cfg, fn, args = build_cell(
                arch, shape_name, multi_pod, overrides
            )
            with mesh:
                lowered = fn.lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = normalize_cost_analysis(compiled.cost_analysis())
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
            weighted = hlo_flops_bytes(hlo)
            rec.update(
                status="ok",
                lower_s=round(t_lower, 2),
                compile_s=round(t_compile, 2),
                grad_accum=cfg.grad_accum,
                memory={
                    k: int(getattr(mem, k))
                    for k in (
                        "argument_size_in_bytes",
                        "output_size_in_bytes",
                        "temp_size_in_bytes",
                        "generated_code_size_in_bytes",
                    )
                    if hasattr(mem, k)
                },
                cost={
                    k: float(v)
                    for k, v in cost.items()
                    if isinstance(v, (int, float)) and k in (
                        "flops", "transcendentals", "bytes accessed",
                        "bytes accessed output", "optimal_seconds",
                    )
                },
                collectives=coll,
                weighted=weighted,  # trip-count-weighted per-device FLOPs/bytes
                hlo_bytes=len(hlo),
            )
        except Exception as e:  # record failures — they are bugs to fix
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       trace=traceback.format_exc()[-3000:])
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = ""
    if status == "ok":
        arg_gb = rec["memory"].get("argument_size_in_bytes", 0) / 2**30
        tmp_gb = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
        extra = (f" args={arg_gb:.2f}GiB temp={tmp_gb:.2f}GiB "
                 f"coll={rec['collectives']['total_bytes'] / 2**30:.2f}GiB "
                 f"compile={rec['compile_s']:.0f}s")
    print(f"[{cell_id}] {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (python literal), for "
                         "§Perf variants; requires --tag")
    ap.add_argument("--tag", default="", help="variant tag for the JSON name")
    args = ap.parse_args()

    import ast
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    meshes = []
    if args.multi_pod or not args.single_pod:
        meshes.append(True)
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    meshes = sorted(set(meshes))  # False (single) first

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    if args.all:
        archs, shapes = sorted(ARCHS), list(SHAPES)

    n_bad = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out, force=args.force,
                               overrides=overrides or None, tag=args.tag)
                if rec["status"] == "error":
                    n_bad += 1
    print(f"done; {n_bad} errors")
    raise SystemExit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
