"""Logical -> physical sharding glue.

Parameter specs are written against logical axis names ("data", "model");
the batch is sharded over every pure-DP axis present in the mesh ("pod"
included when it exists).  Everything resolves against the actual mesh at
launch time, so the same model code runs on (data, model) and
(pod, data, model) meshes — and on any reshape of them (elastic restarts).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh):
    """Axes the global batch is sharded over (pod + data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def batch_spec(mesh: Mesh) -> P:
    return P(batch_axes(mesh))


def resolve_spec(spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that don't exist (e.g. 'pod' on a single-pod mesh)."""
    names = set(mesh.axis_names)

    def fix(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(fix(e) for e in spec))


def param_sharding(specs, mesh: Mesh):
    """Spec pytree -> NamedSharding pytree resolved on this mesh."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, mesh)),
        specs,
        is_leaf=lambda s: isinstance(s, P),
    )


def batch_shardings(batch_tree, mesh: Mesh):
    """Shard every batch input on its leading (batch) dimension."""
    bs = NamedSharding(mesh, P(batch_axes(mesh)))

    def one(x):
        nd = len(x.shape)
        return NamedSharding(mesh, P(batch_axes(mesh), *(None,) * (nd - 1)))

    return jax.tree.map(one, batch_tree)
