"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets the 512-device XLA flag before
calling it; tests and benches keep their single CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: 'pod' = pure data parallelism across pods (param replication,
    gradient all-reduce over ICI/DCN), 'data' = FSDP + batch sharding,
    'model' = TP/EP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic variant: any shape whose axis names are drawn from
    ('pod', 'data', 'model') restores checkpoints cleanly (DESIGN.md §8)."""
    return jax.make_mesh(shape, axes)
