"""Serving launcher: batched decode with merge-sort sampling.

``python -m repro.launch.serve --arch qwen3-0.6b --smoke --tokens 32``

Prefill is run once for the prompt batch, then tokens are decoded
autoregressively with top-k/top-p sampling over the merge-sorted logits.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.registry import ARCHS, smoke_config
from repro.models.transformer import decode_step, init_cache, init_params
from repro.serving.sampling import sample_greedy, sample_topk, sample_topp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--sampler", choices=["greedy", "topk", "topp"],
                    default="topk")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--moe-dispatch", choices=("capacity", "dropless"),
                    default=None,
                    help="override ModelConfig.moe_dispatch (MoE archs)")
    ap.add_argument("--metrics-dir", default="",
                    help="enable repro.obs metrics; JSONL lands here "
                         "(overrides ModelConfig.metrics_dir)")
    ap.add_argument("--profile-steps", type=int, default=0,
                    help="dump a jax.profiler trace covering the first N "
                         "decode steps (under <metrics-dir>/profile)")
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.moe_dispatch is not None:
        import dataclasses

        cfg = dataclasses.replace(cfg, moe_dispatch=args.moe_dispatch)
    metrics_dir = args.metrics_dir or cfg.metrics_dir
    if metrics_dir:
        import dataclasses

        cfg = dataclasses.replace(cfg, metrics_dir=metrics_dir)
        obs.enable(metrics_dir=metrics_dir)

    params, _ = init_params(cfg, jax.random.key(0))
    max_len = args.prompt_len + args.tokens
    cache = init_cache(cfg, args.batch, max_len)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )

    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    key = jax.random.key(42)

    if obs.enabled():
        # Compile-time yardstick for the decode entrypoint's collectives.
        try:
            obs.attach_hlo_report(
                "decode_step",
                step.lower(params, cache, prompts[:, :1]),
                arch=cfg.name,
            )
        except Exception as e:  # report must never kill serving
            obs.log_event(
                "hlo.report_failed", entry="decode_step", error=repr(e)
            )

    profiling = False
    if args.profile_steps > 0:
        obs.start_profile(os.path.join(metrics_dir or ".", "profile"))
        profiling = True

    # teacher-forced prefill through the decode path (batched serving uses
    # prefill_logits + cache population; the smoke driver keeps it simple)
    t0 = time.time()
    logits = None
    with obs.host_span("serve.prefill"):
        for t in range(args.prompt_len):
            logits, cache = step(params, cache, prompts[:, t : t + 1])

    out_tokens = []
    for i in range(args.tokens):
        obs.set_step(i)
        with obs.step_span("decode", i):
            key, sub = jax.random.split(key)
            if args.sampler == "greedy":
                nxt = sample_greedy(logits)
            elif args.sampler == "topk":
                nxt = sample_topk(sub, logits, k=min(50, cfg.vocab),
                                  fanout=cfg.fanout)
            else:
                nxt = sample_topp(sub, logits, p=0.9, k=min(64, cfg.vocab),
                                  fanout=cfg.fanout)
            out_tokens.append(np.asarray(nxt))
            logits, cache = step(
                params, cache, nxt[:, None].astype(jnp.int32)
            )
        if obs.enabled():
            obs.flush()
        if profiling and i + 1 >= args.profile_steps:
            obs.stop_profile()
            profiling = False
    if profiling:
        obs.stop_profile()
    if obs.enabled():
        obs.flush()

    dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {gen[b][:16].tolist()}...")
    assert int(cache.length) == max_len
    return gen


if __name__ == "__main__":
    main()
