"""Serving launcher: continuous-batching decode with merge-based sampling.

``python -m repro.launch.serve --arch qwen3-0.6b --smoke --requests 8``

Requests arrive staggered (``--arrival-every`` engine steps apart) and
are admitted into free KV-pool slots *between* decode steps by the
:class:`~repro.serving.engine.DecodeEngine`: one compiled ragged step
advances every active slot a token at its own position, and the whole
batch's next tokens are drawn with the batched merge-based sampler (one
``merge_kway_ranked`` cut per tournament round, regardless of batch
size).  Finished slots are recycled immediately — no padding to the
slowest request, no recompilation as occupancy churns.

Architectures whose decode cache is not the ``gqa`` family (MLA,
SSM/hybrid) fall back to the original lock-step batch decode: all
requests start together, prefill is teacher-forced through the decode
path, and sampling uses the per-request reference samplers.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.registry import ARCHS, smoke_config
from repro.models.transformer import decode_step, init_cache, init_params
from repro.serving import DecodeEngine, Request
from repro.serving.sampling import sample_greedy, sample_topk, sample_topp


def _serve_continuous(cfg, params, args, metrics_dir):
    """Continuous-batching path (gqa-cache archs)."""
    max_len = args.prompt_len + args.tokens
    eng = DecodeEngine(
        cfg, params, max_len=max_len,
        max_batch=args.max_batch or cfg.max_batch,
        queue_depth=args.queue_depth or cfg.queue_depth,
        sampler=args.sampler, top_k=min(50, cfg.vocab),
        seed=args.seed,
    )
    rng = np.random.default_rng(0)
    arrivals = [
        (i * args.arrival_every,
         Request(i, rng.integers(1, cfg.vocab, args.prompt_len,
                                 dtype=np.int32), args.tokens))
        for i in range(args.requests)
    ]

    if obs.enabled():
        # Compile-time yardstick for the ragged decode entrypoint.
        tokens0 = jnp.zeros((eng.pool.capacity, 1), jnp.int32)
        active0 = jnp.zeros((eng.pool.capacity,), bool)
        obs.attach_hlo_report(  # logs hlo.report_failed on error
            "decode_step_ragged",
            eng._step_fn.lower(params, eng.pool.cache, tokens0, active0),
            arch=cfg.name,
        )

    profiling = False
    if args.profile_steps > 0:
        obs.start_profile(os.path.join(metrics_dir or ".", "profile"))
        profiling = True

    t0 = time.time()
    i = 0
    while True:
        while i < len(arrivals) and arrivals[i][0] <= eng.steps:
            if not eng.submit(arrivals[i][1]):
                break  # queue at depth: retry after the next step
            i += 1
        if eng.pending == 0 and i == len(arrivals):
            break
        obs.set_step(eng.steps)
        with obs.step_span("decode", eng.steps):
            info = eng.step()
        if obs.enabled():
            obs.flush()
        if profiling and eng.steps >= args.profile_steps:
            obs.stop_profile()
            profiling = False
        if info["completed"] and args.verbose:
            print(f"step {eng.steps}: finished rids {info['completed']} "
                  f"(active {info['active']})")
    if profiling:
        obs.stop_profile()
    if obs.enabled():
        obs.flush()

    dt = time.time() - t0
    results = eng.results
    total = sum(len(t) for t in results.values())
    print(f"served {len(results)} requests / {total} tokens in "
          f"{eng.steps} steps, {dt:.2f}s ({total / dt:.1f} tok/s)")
    for rid in sorted(results)[:2]:
        print(f"  rid{rid}: {results[rid][:16]}...")
    eng.scheduler.check_invariants()
    eng.pool.check_invariants()
    return results


def _serve_lockstep(cfg, params, args, metrics_dir):
    """Legacy fixed-batch decode (MLA / SSM / hybrid caches)."""
    batch = args.max_batch or cfg.max_batch
    max_len = args.prompt_len + args.tokens
    cache = init_cache(cfg, batch, max_len)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab, (batch, args.prompt_len)), jnp.int32
    )
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    key = jax.random.key(args.seed)

    if obs.enabled():
        obs.attach_hlo_report(  # logs hlo.report_failed on error
            "decode_step",
            step.lower(params, cache, prompts[:, :1]),
            arch=cfg.name,
        )

    profiling = False
    if args.profile_steps > 0:
        obs.start_profile(os.path.join(metrics_dir or ".", "profile"))
        profiling = True

    t0 = time.time()
    logits = None
    with obs.host_span("serve.prefill"):
        for t in range(args.prompt_len):
            logits, cache = step(params, cache, prompts[:, t : t + 1])

    out_tokens = []
    for i in range(args.tokens):
        obs.set_step(i)
        with obs.step_span("decode", i):
            key, sub = jax.random.split(key)
            if args.sampler == "greedy":
                nxt = sample_greedy(logits)
            elif args.sampler == "topk":
                nxt = sample_topk(sub, logits, k=min(50, cfg.vocab),
                                  fanout=cfg.fanout)
            else:
                nxt = sample_topp(sub, logits, p=0.9, k=min(64, cfg.vocab),
                                  fanout=cfg.fanout)
            out_tokens.append(np.asarray(nxt))
            logits, cache = step(params, cache, nxt[:, None].astype(jnp.int32))
        if obs.enabled():
            obs.flush()
        if profiling and i + 1 >= args.profile_steps:
            obs.stop_profile()
            profiling = False
    if profiling:
        obs.stop_profile()
    if obs.enabled():
        obs.flush()

    dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({batch * args.tokens / dt:.1f} tok/s) [lock-step fallback]")
    for b in range(min(batch, 2)):
        print(f"  seq{b}: {gen[b][:16].tolist()}...")
    assert int(cache.length) == max_len
    return {b: gen[b].tolist() for b in range(batch)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests to serve (continuous-batching path)")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="engine steps between request arrivals")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="KV pool slots (0 = ModelConfig.max_batch)")
    ap.add_argument("--queue-depth", type=int, default=0,
                    help="queue bound (0 = ModelConfig.queue_depth)")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=32,
                    help="max new tokens per request")
    ap.add_argument("--sampler", choices=["greedy", "topk", "topp"],
                    default="topk")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--moe-dispatch", choices=("capacity", "dropless"),
                    default=None,
                    help="override ModelConfig.moe_dispatch (MoE archs)")
    ap.add_argument("--metrics-dir", default="",
                    help="enable repro.obs metrics; JSONL lands here "
                         "(overrides ModelConfig.metrics_dir)")
    ap.add_argument("--profile-steps", type=int, default=0,
                    help="dump a jax.profiler trace covering the first N "
                         "decode steps (under <metrics-dir>/profile)")
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.moe_dispatch is not None:
        cfg = dataclasses.replace(cfg, moe_dispatch=args.moe_dispatch)
    metrics_dir = args.metrics_dir or cfg.metrics_dir
    if metrics_dir:
        cfg = dataclasses.replace(cfg, metrics_dir=metrics_dir)
        obs.enable(metrics_dir=metrics_dir)

    params, _ = init_params(cfg, jax.random.key(0))
    if init_cache(cfg, 1, 8).kind == "gqa":
        return _serve_continuous(cfg, params, args, metrics_dir)
    return _serve_lockstep(cfg, params, args, metrics_dir)


if __name__ == "__main__":
    main()
