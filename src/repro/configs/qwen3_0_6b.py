"""qwen3-0.6b [dense]: qk-norm, GQA, head_dim 128, tied embeddings
(hf:Qwen/Qwen3-0.6B family traits)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    # serving: tiny model, cheap GQA cache -> deep slot pool; fanout 4
    # halves the top-k tournament rounds over the 151936-entry vocab
    # vs pairwise (see BENCH_serve.json / benchmarks/serve_decode.py)
    max_batch=16,
    queue_depth=64,
    fanout=4,
)
