"""qwen1.5-110b [dense]: QKV bias (hf:Qwen/Qwen1.5 family)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    # serving: 80 layers of GQA cache make slots expensive — shallow pool
    max_batch=4,
    queue_depth=16,
)
