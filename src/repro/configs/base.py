"""Model/run configuration: one frozen dataclass drives model init,
forward, sharding, dry-run shapes and the launcher (``--arch <id>``)."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_kind: str = "swiglu"  # swiglu | gelu
    pos_emb: str = "rope"  # rope | sinusoidal
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    # MoE
    moe: bool = False
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0
    moe_ff: int = 0  # routed-expert hidden width
    router_scoring: str = "softmax"  # softmax | sigmoid (V3 aux-free)
    capacity_factor: float = 1.25
    # MLA (deepseek-v3)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # SSM / hybrid
    ssm: bool = False
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 128
    attn_every: int = 0  # hybrid: shared attention block every k layers
    # modality frontend (stub: precomputed embeddings come in as inputs)
    frontend: str = "none"  # none | patches | frames
    frontend_tokens: int = 0  # prefix length supplied as embeddings
    # numerics / perf knobs (§Perf iterates these)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"  # 'bfloat16' for the 671B config
    remat: str = "full"  # full | dots | none
    q_chunk: int = 2048
    kv_chunk: int = 1024
    causal_skip: bool = False
    flash_vjp: bool = False  # flash backward (recompute, no p residuals)
    moe_dispatch_groups: int = 1  # GShard-style local dispatch groups
    moe_dispatch: str = "capacity"  # capacity (fixed slots, drops) |
    #                                 dropless (exact-cut grouped GEMMs)
    use_merge_sort_dispatch: bool = True
    fanout: int = 0  # merge-sort/top-k fan-out (runs merged per pass);
    #                  0 = library defaults (mergesort.DEFAULT_FANOUT,
    #                  topk.TOURNAMENT_FANOUT)
    # serving (repro.serving): continuous-batching decode defaults.
    # max_batch is the KV pool's slot capacity (compiled batch dim of the
    # ragged decode step); queue_depth bounds waiting requests before
    # submit() applies back-pressure.  Per-arch overrides scale these
    # with KV-cache cost; launchers override with --max-batch.
    max_batch: int = 8
    queue_depth: int = 32
    layout: str = "tp"  # 'tp' (model axis = TP/EP) | 'fsdp' (model axis
    #                     joins the batch axes; weights gathered per layer —
    #                     the right mesh use for sub-4B models, see §Perf)
    # observability (repro.obs): '' = metrics off (record points compile
    # to nothing); a directory enables the JSONL sink there.  Launchers
    # override with --metrics-dir.
    metrics_dir: str = ""
    # training
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    adam_dtype: str = "float32"  # 'bfloat16' for the 671B config (as V3 did)
    grad_accum: int = 1

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers), for 6ND."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.ssm and self.attn_every == 0:  # pure SSM
            return emb + self.n_layers * self._mamba_params()
        if self.attn_every:  # hybrid: mamba stack + ONE shared attn block
            return (
                emb
                + self.n_layers * self._mamba_params()
                + self._attn_params()
                + 2 * self.d_model * self.d_ff  # shared block MLP (gelu)
            )
        per_layer = self._attn_params() + self._ffn_params()
        return emb + self.n_layers * per_layer

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        if self.mla:
            qk_hd = self.qk_nope_head_dim + self.qk_rope_head_dim
            return (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * qk_hd
                + d * self.kv_lora_rank
                + d * self.qk_rope_head_dim
                + self.kv_lora_rank * self.n_heads * self.qk_nope_head_dim
                + self.kv_lora_rank * self.n_heads * self.v_head_dim
                + self.n_heads * self.v_head_dim * d
            )
        return d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.moe:
            ff = self.moe_ff or self.d_ff
            routed = self.n_experts * 3 * d * ff
            shared = self.n_shared_experts * 3 * d * ff
            return routed + shared + d * self.n_experts
        mult = 3 if self.mlp_kind == "swiglu" else 2
        return mult * d * self.d_ff

    def _mamba_params(self) -> int:
        d = self.d_model
        d_inner = self.ssm_expand * d
        nheads = d_inner // self.ssm_headdim
        proj_out = d_inner * 2 + 2 * self.ssm_ngroups * self.ssm_state + nheads
        return d * proj_out + d_inner * d

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        ff = self.moe_ff or self.d_ff
        act_ffn = (self.moe_top_k + self.n_shared_experts) * 3 * d * ff
        dense_ffn = 3 * d * self.d_ff if self.first_k_dense else 0
        moe_layers = self.n_layers - self.first_k_dense
        return (
            self.vocab * d * (1 if self.tie_embeddings else 2)
            + moe_layers * (self._attn_params() + act_ffn + d * self.n_experts)
            + self.first_k_dense * (self._attn_params() + dense_ffn)
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
