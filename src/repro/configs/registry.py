"""Architecture registry + input specs for every (arch x shape) cell."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.deepseek_67b import CONFIG as deepseek_67b
from repro.configs.deepseek_v3_671b import CONFIG as deepseek_v3_671b
from repro.configs.dbrx_132b import CONFIG as dbrx_132b
from repro.configs.granite_3_2b import CONFIG as granite_3_2b
from repro.configs.internvl2_26b import CONFIG as internvl2_26b
from repro.configs.mamba2_2_7b import CONFIG as mamba2_2_7b
from repro.configs.musicgen_medium import CONFIG as musicgen_medium
from repro.configs.qwen3_0_6b import CONFIG as qwen3_0_6b
from repro.configs.qwen15_110b import CONFIG as qwen15_110b
from repro.configs.zamba2_1_2b import CONFIG as zamba2_1_2b

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        musicgen_medium,
        qwen3_0_6b,
        deepseek_67b,
        qwen15_110b,
        granite_3_2b,
        deepseek_v3_671b,
        dbrx_132b,
        internvl2_26b,
        zamba2_1_2b,
        mamba2_2_7b,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cell_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is this (arch x shape) cell runnable?  long_500k needs a
    sub-quadratic decode path: SSM/hybrid only (DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.ssm:
        return False, "pure full-attention arch: no sub-quadratic 500k path"
    return True, ""


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    over: dict = dict(
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab=256,
        q_chunk=32,
        kv_chunk=32,
        remat="none",
    )
    if cfg.mla:
        over.update(
            n_heads=4, n_kv_heads=4, q_lora_rank=32, kv_lora_rank=16,
            qk_nope_head_dim=8, qk_rope_head_dim=8, v_head_dim=8,
        )
    elif not cfg.ssm:
        over.update(n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4)
        if cfg.head_dim:
            over.update(head_dim=16)
    if cfg.moe:
        over.update(n_experts=4, moe_top_k=2, moe_ff=32)
        if cfg.first_k_dense:
            over.update(first_k_dense=1, n_layers=3)
    if cfg.ssm:
        over.update(ssm_state=16, ssm_headdim=16, ssm_chunk=8)
        if cfg.attn_every:
            over.update(attn_every=2, n_heads=4, n_kv_heads=4, d_ff=128)
    if cfg.frontend != "none":
        over.update(frontend_tokens=8)
    return dataclasses.replace(cfg, **over)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, for_smoke=False):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: the training batch.  decode: (tokens, cache) for
    ``serve_step`` — one new token against a seq_len-deep cache.
    """
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind in ("train", "prefill"):
        batch = {
            "tokens": tok,
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
        if cfg.frontend != "none":
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
            )
        return batch
    # decode: one token + cache of depth seq_len
    from repro.models.transformer import init_cache

    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, s, dtype=jnp.bfloat16)
    )
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": cache,
    }
