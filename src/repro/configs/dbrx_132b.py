"""dbrx-132b [moe]: 16 experts top-4, fine-grained (hf:databricks/dbrx-base)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=True,
    n_experts=16,
    moe_top_k=4,
    moe_ff=10752,
    rope_theta=5e5,
    moe_dispatch="dropless",  # 16-way top-4 routing skews hard; exact cuts
)
