"""mamba2-2.7b [ssm]: SSD, attention-free (arXiv:2405.21060).
The paper's merge technique does not apply inside the SSD recurrence
(DESIGN.md §6); serving/sampling and the data pipeline still use it."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    ssm=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    tie_embeddings=True,
)
