"""internvl2-26b [vlm]: InternLM2-20B backbone (arXiv:2404.16821).
InternViT frontend is a stub: patch embeddings arrive precomputed for the
first ``frontend_tokens`` positions."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    frontend="patches",
    frontend_tokens=256,
)
