"""deepseek-v3-671b [moe]: MLA + 1 shared + 256 routed top-8 experts,
sigmoid aux-free routing, first 3 layers dense (arXiv:2412.19437).
MTP head is a config option, off for the assigned shapes (matches public
inference configs).  Adam moments in bf16 as in the V3 report."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,          # dense (first_k_dense) layers
    vocab=129280,
    moe=True,
    n_experts=256,
    moe_top_k=8,
    n_shared_experts=1,
    first_k_dense=3,
    moe_ff=2048,
    router_scoring="sigmoid",
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    adam_dtype="bfloat16",
    param_dtype="bfloat16",
    moe_dispatch="dropless",  # 256 fine-grained experts: capacity slots
    #                           waste ~E/k x memory; exact cuts don't
    # serving: MLA cache (lock-step fallback path) — modest fixed batch
    max_batch=4,
    queue_depth=16,
)
