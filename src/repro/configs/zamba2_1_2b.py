"""zamba2-1.2b [hybrid]: Mamba2 stack + shared attention block every 6
layers (arXiv:2411.15242; LoRA adapters on the shared block are a
documented simplification — weights fully shared here)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm=True,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    attn_every=6,
    mlp_kind="gelu",
)
