"""musicgen-medium [audio]: decoder-only over EnCodec tokens
(arXiv:2306.05284).  Text/audio conditioning frontend is a stub: the first
``frontend_tokens`` positions receive precomputed frame embeddings."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    mlp_kind="gelu",
    pos_emb="sinusoidal",
    frontend="frames",
    frontend_tokens=256,
)
