"""Distributed merge/sort on an 8-device host mesh (the shard_map layer).

Demonstrates the ``strategy=`` switch of ``repro.distributed``:
``allgather`` replicates the runs (O(N) per device), ``corank``
distributes the partition search, and ``exchange`` ships each device
exactly its N/p-element block with the splitter-driven balanced
all_to_all — no replication.

    PYTHONPATH=src python examples/distributed_sort.py
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map

from repro.distributed import (
    distributed_merge,
    sharded_sort,
    sharded_sort_host,
)

mesh = Mesh(np.array(jax.devices()), ("x",))
p = len(jax.devices())
rng = np.random.default_rng(0)
m = n = 512 * p

a = np.sort(rng.integers(0, 10_000, m)).astype(np.int32)
b = np.sort(rng.integers(0, 10_000, n)).astype(np.int32)
want_merge = np.sort(np.concatenate([a, b]), kind="stable")

for strategy in ("allgather", "corank"):
    merged = jax.jit(
        shard_map(
            lambda aa, bb: distributed_merge(aa, bb, "x", strategy=strategy),
            mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x"),
        )
    )(jnp.asarray(a), jnp.asarray(b))
    assert (np.asarray(merged) == want_merge).all()
    print(f"distributed merge [{strategy:9s}] over {p} devices: ok "
          f"(each device produced exactly {(m + n) // p} elements)")

x = rng.integers(-1000, 1000, p * 1024).astype(np.int32)
for strategy in ("allgather", "exchange"):
    s = jax.jit(
        shard_map(
            lambda xx: sharded_sort(xx, "x", strategy=strategy),
            mesh=mesh, in_specs=(P("x"),), out_specs=P("x"),
        )
    )(jnp.asarray(x))
    assert (np.asarray(s) == np.sort(x, kind="stable")).all()
    print(f"sharded sort    [{strategy:9s}] over {p} devices: ok")

# Uneven / non-power-of-two sizes via the host wrapper's sentinel padding.
y = rng.normal(size=10_001).astype(np.float32)
sy = sharded_sort_host(jnp.asarray(y), strategy="exchange")
assert (np.asarray(sy) == np.sort(y, kind="stable")).all()
print(f"sharded_sort_host on n={len(y)} (uneven remainder): ok")
