"""Distributed merge/sort on an 8-device host mesh (the shard_map layer).

    PYTHONPATH=src python examples/distributed_sort.py
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map

from repro.core.distributed import (
    distributed_co_rank,
    distributed_merge,
    distributed_sort,
)

mesh = Mesh(np.array(jax.devices()), ("x",))
rng = np.random.default_rng(0)
m = n = 512 * 8

a = np.sort(rng.integers(0, 10_000, m)).astype(np.int32)
b = np.sort(rng.integers(0, 10_000, n)).astype(np.int32)

merged = jax.jit(
    shard_map(
        lambda aa, bb: distributed_merge(aa, bb, "x"),
        mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x"),
    )
)(jnp.asarray(a), jnp.asarray(b))
assert (np.asarray(merged) == np.sort(np.concatenate([a, b]), kind="stable")).all()
print("distributed merge over 8 devices: ok (each device produced exactly",
      (m + n) // 8, "elements)")

x = rng.integers(-1000, 1000, 8 * 1024).astype(np.int32)
s = jax.jit(
    shard_map(
        lambda xx: distributed_sort(xx, "x"),
        mesh=mesh, in_specs=(P("x"),), out_specs=P("x"),
    )
)(jnp.asarray(x))
assert (np.asarray(s) == np.sort(x, kind="stable")).all()
print("distributed sort over 8 devices: ok")
