"""Batched serving example: decode with KV cache + merge-sort top-k/top-p.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch import serve

if __name__ == "__main__":
    serve.main([
        "--arch", "qwen3-0.6b", "--smoke",
        "--batch", "4", "--prompt-len", "8", "--tokens", "24",
        "--sampler", "topp",
    ])
