"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on CPU with the full production substrate (data pipeline with merge-sort
length bucketing, AdamW, checkpoints, restart).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import sys

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS
from repro.launch import train as train_launch

# ~100M params: 12 x d512 dense blocks + 32k vocab (2 x 16M embeddings)
CONFIG_100M = ModelConfig(
    name="lm-100m",
    family="dense",
    n_layers=12,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    d_ff=2048,
    vocab=32000,
    q_chunk=128,
    kv_chunk=128,
    remat="none",
)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/mergeflow_100m")
    args = ap.parse_args()
    print(f"params: {CONFIG_100M.param_count() / 1e6:.1f}M")
    ARCHS["lm-100m"] = CONFIG_100M  # register for the launcher
    losses = train_launch.main([
        "--arch", "lm-100m",
        "--steps", str(args.steps),
        "--batch", "2",
        "--seq", "128",
        "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
    ])
    assert losses[-1] < losses[0], "loss must descend"
