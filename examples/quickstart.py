"""Quickstart: the paper's co-rank merge in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    co_rank,
    merge_by_ranking,
    merge_partitioned,
    merge_sort,
    merge_topk,
    partition_bounds,
)
from repro.kernels.merge import merge_pallas

rng = np.random.default_rng(0)
a = jnp.asarray(np.sort(rng.integers(0, 100, 1000)), jnp.int32)
b = jnp.asarray(np.sort(rng.integers(0, 100, 1500)), jnp.int32)

# 1. Co-ranking (Algorithm 1): which prefixes of A and B make up C[0:800]?
res = co_rank(800, a, b)
print(f"co_rank(i=800) -> j={int(res.j)}, k={int(res.k)} "
      f"({int(res.iterations)} iterations, bound=log2 min(m,n)~10)")

# 2. Perfectly load-balanced parallel merge (Algorithm 2): 8 lanes, each
#    merges exactly ceil(2500/8) elements.
c = merge_partitioned(a, b, p=8)
bounds = np.asarray(partition_bounds(2500, 8))
print("per-PE elements:", np.diff(bounds).tolist())
assert (np.asarray(c) == np.sort(np.concatenate([a, b]), kind="stable")).all()

# 3. The TPU kernel (Pallas, interpret mode on CPU): same answer.
ck = merge_pallas(a, b, tile=256)
assert (np.asarray(ck) == np.asarray(c)).all()
print("pallas kernel matches:", True)

# 4. Everything built on it: stable sort and top-k.
x = jnp.asarray(rng.standard_normal(4096), jnp.float32)
s = merge_sort(x)
vals, idx = merge_topk(x, 5)
print("top-5:", np.asarray(vals).round(3).tolist())
print("ok")
